"""Checkpoint/restart, preemption, straggler, and resume-determinism tests."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import max_err
from repro import checkpoint as ckpt
from repro import configs
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.runtime.steps import make_train_step
from repro.runtime.trainer import StragglerMonitor, Trainer, TrainerConfig


def _tiny_cfg():
    return dataclasses.replace(configs.smoke_config("granite_3_2b"),
                               dtype=jnp.float32, num_layers=2, d_model=32,
                               num_heads=2, num_kv_heads=2, d_ff=64,
                               vocab_size=64)


def _trainer(tmp, ckpt_every=5, seed=0):
    cfg = _tiny_cfg()
    arts = make_train_step(cfg, opt=AdamWConfig(lr=1e-3), impl="xla",
                           xla_chunk=32)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4,
                    seed=seed)
    tcfg = TrainerConfig(ckpt_dir=str(tmp), ckpt_every=ckpt_every,
                         log_every=1000, async_ckpt=False)
    return Trainer(arts=arts, data_cfg=dc, tcfg=tcfg)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32), "d": jnp.float32(3.5)}}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = ckpt.restore(str(tmp_path), 7, like)
    assert all(max_err(a, b) == 0 for a, b in
               zip(jax.tree.leaves(out), jax.tree.leaves(tree)))


def test_atomic_commit_ignores_partial(tmp_path):
    """A stale .tmp dir (simulated crash mid-save) must be invisible."""
    tree = {"w": jnp.ones((4,))}
    ckpt.save(str(tmp_path), 3, tree)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_truncated_checkpoint_raises_corrupt(tmp_path):
    """A truncated arrays.npz must fail the digest check with the typed
    error, not explode inside numpy deserialization."""
    tree = {"a": jnp.arange(64.0), "b": jnp.ones((8, 8))}
    ckpt.save(str(tmp_path), 2, tree)
    arrays = tmp_path / "step_00000002" / "arrays.npz"
    blob = arrays.read_bytes()
    arrays.write_bytes(blob[:len(blob) // 2])
    with pytest.raises(ckpt.CorruptCheckpointError, match="integrity"):
        ckpt.restore(str(tmp_path), 2, jax.tree.map(jnp.zeros_like, tree))


def test_bitflip_checkpoint_raises_corrupt(tmp_path):
    """A single flipped byte in the payload must be caught too."""
    tree = {"w": jnp.ones((16,))}
    ckpt.save(str(tmp_path), 1, tree)
    arrays = tmp_path / "step_00000001" / "arrays.npz"
    blob = bytearray(arrays.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    arrays.write_bytes(bytes(blob))
    with pytest.raises(ckpt.CorruptCheckpointError):
        ckpt.restore(str(tmp_path), 1, jax.tree.map(jnp.zeros_like, tree))


def test_predigest_checkpoint_still_restores(tmp_path):
    """Checkpoints written before the digest field existed (no "digest" key
    in metadata.json) restore without complaint — integrity is opt-out for
    legacy artifacts, never a migration break."""
    import json
    tree = {"w": jnp.full((4,), 2.0)}
    ckpt.save(str(tmp_path), 5, tree)
    meta_path = tmp_path / "step_00000005" / "metadata.json"
    meta = json.loads(meta_path.read_text())
    del meta["digest"]
    meta_path.write_text(json.dumps(meta))
    out = ckpt.restore(str(tmp_path), 5, jax.tree.map(jnp.zeros_like, tree))
    assert max_err(out["w"], tree["w"]) == 0


@pytest.mark.slow  # three 5-10 step training runs (~8s)
def test_resume_determinism(tmp_path):
    """train(10) ≡ train(5) + restart + train(5..10), bit-for-bit."""
    t1 = _trainer(tmp_path / "a", ckpt_every=100)
    r1 = t1.run(10)

    t2 = _trainer(tmp_path / "b", ckpt_every=5)
    t2.run(5)
    t3 = _trainer(tmp_path / "b", ckpt_every=5)  # resumes from step_00000004
    r3 = t3.run(10)
    errs = [max_err(a, b) for a, b in zip(jax.tree.leaves(r1["params"]),
                                          jax.tree.leaves(r3["params"]))]
    assert max(errs) < 1e-6, f"resume diverged: {max(errs)}"


@pytest.mark.slow  # two trainer builds → two train-step compiles (~6s)
def test_preemption_checkpoints_and_exits(tmp_path):
    t = _trainer(tmp_path, ckpt_every=1000)
    t.hooks["pre_step"] = lambda step: (t.request_preemption()
                                        if step == 3 else None)
    r = t.run(100)
    assert r["preempted"]
    assert r["stop_step"] <= 5
    assert ckpt.latest_step(str(tmp_path)) is not None
    # a fresh trainer must resume from the preemption point, not step 0
    t2 = _trainer(tmp_path, ckpt_every=1000)
    r2 = t2.run(6)
    assert r2["stop_step"] == 6


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=3.0)
    for i in range(10):
        mon.observe(i, 0.1)
    assert not mon.flagged
    mon.observe(10, 0.5)  # 5× median
    assert len(mon.flagged) == 1 and mon.flagged[0][0] == 10


@pytest.mark.slow  # 10 live train steps + an injected 0.5s stall
def test_straggler_injection_in_trainer(tmp_path):
    import time
    t = _trainer(tmp_path, ckpt_every=1000)
    t.hooks["pre_step"] = lambda step: time.sleep(0.5) if step == 8 else None
    r = t.run(10)
    assert any(s[0] == 8 for s in r["stragglers"]), r["stragglers"]


def test_data_pipeline_determinism():
    from repro.data import make_batch
    dc = DataConfig(vocab_size=100, seq_len=16, global_batch=2, seed=3)
    b1 = make_batch(dc, 5)
    b2 = make_batch(dc, 5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(dc, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
