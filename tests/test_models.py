"""Per-arch smoke tests (reduced configs) + decode-parity + MoE dispatch."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import max_err
from repro import configs
from repro.models import lm, moe
from repro.models.layers import Ctx


def _f32(cfg):
    return dataclasses.replace(cfg, dtype=jnp.float32, remat=False)


# heavyweight smoke configs (wide recurrences / vision frontends / MoE /
# redundant dense geometries) cost 3-11s apiece on CPU — slow tier. The
# default run keeps granite (the canonical dense arch) only; MoE *math* stays
# covered by the dispatch unit tests below, and every other arch (incl. the
# qwen3 qk_norm variant) runs in the slow tier / CI slow job.
_HEAVY = {"recurrentgemma_2b", "llava_next_34b", "falcon_mamba_7b",
          "dbrx_132b", "hubert_xlarge", "deepseek_moe_16b", "deepseek_67b",
          "deepseek_coder_33b", "qwen3_14b"}


def _arch_params(names):
    return [pytest.param(n, marks=pytest.mark.slow) if n in _HEAVY else n
            for n in names]


def _batch(key, cfg, b=2, s=64):
    batch = {}
    if cfg.frontend:
        batch["embeds"] = jax.random.normal(key, (b, s, lm.FRONTEND_DIM))
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("name", _arch_params(configs.ARCHS))
def test_arch_smoke_train_step_shapes_and_finite(rng_key, name):
    """One forward/loss step on CPU: output shapes + no NaNs (assignment req)."""
    cfg = _f32(configs.smoke_config(name))
    params, specs = lm.init_params(cfg, rng_key)
    # specs mirror params structure
    assert set(jax.tree.structure(params).node_data()[1] or []) == \
        set(jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, tuple)
            ).node_data()[1] or [])
    batch = _batch(rng_key, cfg)
    ctx = Ctx(impl="xla", xla_chunk=32, block_q=32, block_kv=32)
    logits, _, _ = lm.forward(cfg, params, ctx, tokens=batch.get("tokens"),
                              embeds=batch.get("embeds"))
    assert logits.shape[:2] == (2, 64)
    assert logits.shape[2] >= cfg.vocab_size
    loss, metrics = lm.loss_fn(cfg, params, batch, ctx)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: lm.loss_fn(cfg, p, batch, ctx)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)


@pytest.mark.parametrize("name", _arch_params(
    [a for a in configs.ARCHS if configs.smoke_config(a).has_decode]))
def test_arch_decode_parity(rng_key, name):
    """prefill + step-by-step decode ≡ teacher-forced forward logits."""
    cfg = _f32(configs.smoke_config(name))
    if cfg.moe is not None:  # avoid capacity drops (train-only semantics)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params, _ = lm.init_params(cfg, rng_key)
    b, s_prompt, n_gen = 2, 32, 4
    s_total = s_prompt + n_gen
    tokens = jax.random.randint(rng_key, (b, s_total), 0, cfg.vocab_size)
    ctx = Ctx(impl="xla", xla_chunk=16, block_q=16, block_kv=16)
    logits_full, _, _ = lm.forward(cfg, params, ctx, tokens=tokens)
    caches = lm.init_cache(cfg, b, s_total)
    last, caches = lm.prefill(cfg, params, ctx, tokens=tokens[:, :s_prompt],
                              caches=caches)
    assert max_err(last, logits_full[:, s_prompt - 1]) < 2e-4
    for t in range(n_gen):
        pos = s_prompt + t
        lg, caches = lm.decode_step(cfg, params, ctx, tokens[:, pos], caches,
                                    pos)
        assert max_err(lg, logits_full[:, pos]) < 2e-4, f"step {t}"


@pytest.mark.slow  # 64-token decode loop over the hybrid stack (~18s)
def test_sliding_window_ring_cache(rng_key):
    """recurrentgemma ring cache: decode far past the window stays correct."""
    cfg = _f32(configs.smoke_config("recurrentgemma_2b"))
    # window 32 (from smoke cfg); decode 16 tokens past a 48-token prompt so the
    # ring wraps. Compare against teacher-forced full forward.
    params, _ = lm.init_params(cfg, rng_key)
    b, s_prompt, n_gen = 1, 48, 16
    tokens = jax.random.randint(rng_key, (b, s_prompt + n_gen), 0,
                                cfg.vocab_size)
    ctx = Ctx(impl="xla", xla_chunk=16, block_q=16, block_kv=16)
    logits_full, _, _ = lm.forward(cfg, params, ctx, tokens=tokens)
    caches = lm.init_cache(cfg, b, s_prompt + n_gen)
    _, caches = lm.prefill(cfg, params, ctx, tokens=tokens[:, :s_prompt],
                           caches=caches)
    for t in range(n_gen):
        pos = s_prompt + t
        lg, caches = lm.decode_step(cfg, params, ctx, tokens[:, pos], caches,
                                    pos)
        assert max_err(lg, logits_full[:, pos]) < 2e-4, f"step {t}"
    # the attention cache stayed at window size, not prompt+gen size
    k_shapes = [x.shape for x in jax.tree.leaves(caches)
                if hasattr(x, "ndim") and x.ndim == 5]  # stacked [n_super,B,H,S,D]
    assert k_shapes and all(s[3] == cfg.attn_window for s in k_shapes), k_shapes


@pytest.mark.parametrize("name", [pytest.param("dbrx_132b",
                                               marks=pytest.mark.slow),
                                  "deepseek_moe_16b"])
def test_moe_dispatch_matches_dense_oracle(rng_key, name):
    """GShard grouped-einsum dispatch ≡ dense per-expert loop (no drops)."""
    cfg = _f32(configs.smoke_config(name))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p, _ = moe.init_moe(rng_key, cfg, jnp.float32)
    x = jax.random.normal(rng_key, (2, 64, cfg.d_model))
    out, metrics = moe.apply_moe(p, x, Ctx(), cfg)
    ref = moe.moe_reference(p, x, cfg)
    assert max_err(out, ref) < 1e-5
    assert float(metrics["moe_dropped"]) < 1e-6


def test_moe_capacity_drops_bounded(rng_key):
    """At cf=1.0 with random routing some tokens drop, but the fraction must
    stay well below 50% and the layer must stay finite."""
    cfg = _f32(configs.smoke_config("deepseek_moe_16b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    p, _ = moe.init_moe(rng_key, cfg, jnp.float32)
    x = jax.random.normal(rng_key, (2, 128, cfg.d_model))
    out, metrics = moe.apply_moe(p, x, Ctx(), cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert 0.0 <= float(metrics["moe_dropped"]) < 0.5


@pytest.mark.slow  # two full loss+grad compiles of the granite stack
def test_remat_matches_no_remat(rng_key):
    """jax.checkpoint on superblocks must not change values or grads."""
    cfg0 = dataclasses.replace(configs.smoke_config("granite_3_2b"),
                               dtype=jnp.float32, remat=False)
    cfg1 = dataclasses.replace(cfg0, remat=True)
    params, _ = lm.init_params(cfg0, rng_key)
    batch = _batch(rng_key, cfg0)
    ctx = Ctx(impl="xla", xla_chunk=32)
    l0, g0 = jax.value_and_grad(lambda p: lm.loss_fn(cfg0, p, batch, ctx)[0])(params)
    l1, g1 = jax.value_and_grad(lambda p: lm.loss_fn(cfg1, p, batch, ctx)[0])(params)
    assert max_err(l0, l1) < 1e-6
    assert max(max_err(a, b) for a, b in zip(jax.tree.leaves(g0),
                                             jax.tree.leaves(g1))) < 1e-5


def test_vocab_padding(rng_key):
    """vocab_pad_to pads the embedding/head; loss masks the padding."""
    cfg = _f32(configs.smoke_config("granite_3_2b"))  # vocab 251 (odd)
    params, _ = lm.init_params(cfg, rng_key, vocab_pad_to=16)
    assert params["embed"].shape[0] == 256
    batch = _batch(rng_key, cfg)
    loss, _ = lm.loss_fn(cfg, params, batch, Ctx(impl="xla", xla_chunk=32))
    assert bool(jnp.isfinite(loss))


# ---------------------------------------------------------------------------
# recurrent mixers: full-sequence scan ≡ T sequential decode steps
# ---------------------------------------------------------------------------
# The serving packed-prefill path leans on this equivalence (a span's scan
# must leave exactly the state a step-by-step decode would) — pin it at the
# mixer level where a failure localizes to one recurrence, not a whole LM.

def test_rglru_step_equals_scan(rng_key):
    from repro.models import rglru
    cfg = _f32(configs.smoke_config("recurrentgemma_2b"))
    p, _ = rglru.init_rglru(rng_key, cfg, jnp.float32)
    b, s = 2, 12
    x = jax.random.normal(jax.random.fold_in(rng_key, 1), (b, s, cfg.d_model))
    ctx = Ctx(impl="xla")
    out_scan, cache_scan = rglru.apply_rglru(
        p, x, ctx, cfg, cache=rglru.init_rglru_cache(cfg, b))
    cache = rglru.init_rglru_cache(cfg, b)
    ctx_d = dataclasses.replace(ctx, decode=True)
    for t in range(s):
        out_t, cache = rglru.apply_rglru(p, x[:, t:t + 1], ctx_d, cfg,
                                         cache=cache)
        assert max_err(out_t[:, 0], out_scan[:, t]) < 2e-5, f"step {t}"
    assert max_err(cache["h"], cache_scan["h"]) < 2e-5
    assert max_err(cache["conv"], cache_scan["conv"]) < 2e-5


def test_mamba_step_equals_scan(rng_key):
    from repro.models import mamba
    cfg = _f32(configs.smoke_config("falcon_mamba_7b"))
    p, _ = mamba.init_mamba(rng_key, cfg, jnp.float32)
    b, s = 2, 12
    x = jax.random.normal(jax.random.fold_in(rng_key, 1), (b, s, cfg.d_model))
    ctx = Ctx(impl="xla")
    out_scan, cache_scan = mamba.apply_mamba(
        p, x, ctx, cfg, cache=mamba.init_mamba_cache(cfg, b))
    cache = mamba.init_mamba_cache(cfg, b)
    ctx_d = dataclasses.replace(ctx, decode=True)
    for t in range(s):
        out_t, cache = mamba.apply_mamba(p, x[:, t:t + 1], ctx_d, cfg,
                                         cache=cache)
        assert max_err(out_t[:, 0], out_scan[:, t]) < 2e-5, f"step {t}"
    assert max_err(cache["h"], cache_scan["h"]) < 2e-5
    assert max_err(cache["conv"], cache_scan["conv"]) < 2e-5
