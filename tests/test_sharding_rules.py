"""Sharding-rule engine: divisibility fallback, axis uniqueness, profiles.

Pure-host logic tests (build a Mesh over 1 CPU device via AbstractMesh-style
shape reasoning is not needed — Mesh construction only needs device objects).
"""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed.sharding import default_rules, _fsdp_rules


def fake_mesh(shape, axes):
    # sharding specs only consult mesh.shape — build a host-only mesh by
    # tiling the single CPU device (never used for execution).
    devs = np.tile(np.array(jax.devices()[:1]), int(np.prod(shape)))
    return jax.sharding.Mesh(devs.reshape(shape), axes)


MESH = fake_mesh((16, 16), ("data", "model"))
MESH3 = fake_mesh((2, 16, 16), ("pod", "data", "model"))

# Known seed-state disagreement between these expectations and the rule engine
# (it FSDP-shards the leading embed/vocab axis over (data, model) where the
# tests expect pure TP / replication; the sharded-vs-single-device numeric
# mismatch in tests/test_distributed.py shares the root cause). Tracked as a
# ROADMAP open item; xfail keeps the regression visible without masking it.
_seed_rules_bug = pytest.mark.xfail(
    reason="seed: sharding-rule engine vs. test expectations (see ROADMAP)",
    strict=False)


@_seed_rules_bug
def test_divisible_dims_shard():
    cfg = configs.get_config("granite_3_2b")
    rules = default_rules(MESH, cfg)
    # d_ff 8192 % 16 == 0 → mlp shards on model
    assert rules.spec_for(("embed", "mlp"), (2048, 8192)) == P(None, "model")
    # batch over data
    assert rules.spec_for(("batch", None), (256, 4096)) == P("data", None)


def test_non_divisible_falls_back_to_replication():
    import dataclasses
    cfg = dataclasses.replace(configs.get_config("recurrentgemma_2b"),
                              ctx_parallel_attn=False)  # 10 heads, no CP
    rules = default_rules(MESH, cfg)
    spec = rules.spec_for(("batch", "heads", "seq_full", "head_dim"),
                          (256, 10, 4096, 256))
    assert spec == P("data", None, None, None)
    assert rules.rules["heads"] is None  # head rule disabled at build time


def test_non_divisible_heads_with_ctx_parallel_shard_seq():
    # the promoted production config: attention q-rows shard over model
    cfg = configs.get_config("recurrentgemma_2b")  # ctx_parallel_attn=True
    rules = default_rules(MESH, cfg)
    spec = rules.spec_for(("batch", "heads", "seq_full", "head_dim"),
                          (256, 10, 4096, 256))
    assert spec == P("data", None, "model", None)


@_seed_rules_bug
def test_axis_used_at_most_once():
    cfg = configs.get_config("deepseek_moe_16b")   # kv_heads=16 divisible
    rules = default_rules(MESH, cfg)
    spec = rules.spec_for(("batch", "kv_heads", "kv_cache_seq", "head_dim"),
                          (128, 16, 32768, 128))
    # kv_heads takes 'model'; cache seq must NOT reuse it
    assert spec == P("data", "model", None, None)

    cfg2 = configs.get_config("granite_3_2b")      # kv_heads=8 not divisible
    rules2 = default_rules(MESH, cfg2)
    spec2 = rules2.spec_for(("batch", "kv_heads", "kv_cache_seq", "head_dim"),
                            (128, 8, 32768, 64))
    # kv_heads fell back → cache seq picks up 'model' (distributed decode)
    assert spec2 == P("data", None, "model", None)


@_seed_rules_bug
def test_multipod_batch_spans_pod_and_data():
    cfg = configs.get_config("granite_3_2b")
    rules = default_rules(MESH3, cfg)
    assert rules.spec_for(("batch", None), (256, 4096)) == \
        P(("pod", "data"), None)


def test_fsdp_profile_shards_params_over_both_axes():
    import dataclasses
    cfg = dataclasses.replace(configs.get_config("deepseek_67b"),
                              sharding_profile="fsdp")
    rules = default_rules(MESH, cfg)
    # params: embed dim over (data, model) = 256-way ZeRO-3
    assert rules.spec_for(("embed", "mlp"), (8192, 22016)) == \
        P(("data", "model"), None)
    # batch over the same 256-way product
    assert rules.spec_for(("batch", None), (256, 4096)) == \
        P(("data", "model"), None)
    # no TP anywhere
    assert rules.rules["heads"] is None and rules.rules["mlp"] is None


@_seed_rules_bug
def test_vocab_padding_divisibility():
    cfg = configs.get_config("granite_3_2b")  # vocab 49155 (odd)
    rules = default_rules(MESH, cfg)
    assert rules.spec_for(("vocab", "embed"), (49155, 2048)) == P(None, None)
    assert rules.spec_for(("vocab", "embed"), (49168, 2048)) == P("model", None)


def test_all_archs_build_rules_on_both_meshes():
    for name in configs.ARCHS:
        cfg = configs.get_config(name)
        for mesh in (MESH, MESH3):
            rules = default_rules(mesh, cfg)
            assert "batch" in rules.rules
