"""Sharding-rule engine: divisibility fallback, axis uniqueness, profiles.

Pure-host logic tests (build a Mesh over 1 CPU device via AbstractMesh-style
shape reasoning is not needed — Mesh construction only needs device objects).
"""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed.sharding import default_rules, _fsdp_rules, vocab_pad_for


def fake_mesh(shape, axes):
    # sharding specs only consult mesh.shape — build a host-only mesh by
    # tiling the single CPU device (never used for execution).
    devs = np.tile(np.array(jax.devices()[:1]), int(np.prod(shape)))
    return jax.sharding.Mesh(devs.reshape(shape), axes)


MESH = fake_mesh((16, 16), ("data", "model"))
MESH3 = fake_mesh((2, 16, 16), ("pod", "data", "model"))


def test_divisible_dims_shard():
    cfg = configs.get_config("granite_3_2b")
    rules = default_rules(MESH, cfg)
    # d_ff 8192 % 16 == 0 → mlp shards on model
    assert rules.spec_for(("embed", "mlp"), (2048, 8192)) == P(None, "model")
    # batch over data
    assert rules.spec_for(("batch", None), (256, 4096)) == P("data", None)


def test_non_divisible_falls_back_to_replication():
    import dataclasses
    cfg = dataclasses.replace(configs.get_config("recurrentgemma_2b"),
                              ctx_parallel_attn=False)  # 10 heads, no CP
    rules = default_rules(MESH, cfg)
    spec = rules.spec_for(("batch", "heads", "seq_full", "head_dim"),
                          (256, 10, 4096, 256))
    assert spec == P("data", None, None, None)
    assert rules.rules["heads"] is None  # head rule disabled at build time


def test_non_divisible_heads_with_ctx_parallel_shard_seq():
    # the promoted production config: attention q-rows shard over model
    cfg = configs.get_config("recurrentgemma_2b")  # ctx_parallel_attn=True
    rules = default_rules(MESH, cfg)
    spec = rules.spec_for(("batch", "heads", "seq_full", "head_dim"),
                          (256, 10, 4096, 256))
    assert spec == P("data", None, "model", None)


def test_axis_used_at_most_once():
    cfg = configs.get_config("deepseek_moe_16b")   # kv_heads=16 divisible
    rules = default_rules(MESH, cfg)
    spec = rules.spec_for(("batch", "kv_heads", "kv_cache_seq", "head_dim"),
                          (128, 16, 32768, 128))
    # kv_heads takes 'model'; cache seq must NOT reuse it
    assert spec == P("data", "model", None, None)

    cfg2 = configs.get_config("granite_3_2b")      # kv_heads=8 not divisible
    rules2 = default_rules(MESH, cfg2)
    spec2 = rules2.spec_for(("batch", "kv_heads", "kv_cache_seq", "head_dim"),
                            (128, 8, 32768, 64))
    # kv_heads fell back → cache seq picks up 'model' (distributed decode)
    assert spec2 == P("data", None, "model", None)


def test_multipod_batch_spans_pod_and_data():
    cfg = configs.get_config("granite_3_2b")
    rules = default_rules(MESH3, cfg)
    assert rules.spec_for(("batch", None), (256, 4096)) == \
        P(("pod", "data"), None)


def test_fsdp_profile_shards_params_over_both_axes():
    import dataclasses
    cfg = dataclasses.replace(configs.get_config("deepseek_67b"),
                              sharding_profile="fsdp")
    rules = default_rules(MESH, cfg)
    # params: embed dim over (data, model) = 256-way ZeRO-3
    assert rules.spec_for(("embed", "mlp"), (8192, 22016)) == \
        P(("data", "model"), None)
    # batch over the same 256-way product
    assert rules.spec_for(("batch", None), (256, 4096)) == \
        P(("data", "model"), None)
    # no TP anywhere
    assert rules.rules["heads"] is None and rules.rules["mlp"] is None


def test_vocab_padding_divisibility():
    cfg = configs.get_config("granite_3_2b")  # vocab 49155 (odd)
    rules = default_rules(MESH, cfg)
    assert rules.spec_for(("vocab", "embed"), (49155, 2048)) == P(None, None)
    assert rules.spec_for(("vocab", "embed"), (49168, 2048)) == P("model", None)


def test_fsdp_profile_needs_explicit_opt_in():
    """The seed bug: sharding_profile="fsdp" alone (a scale annotation) must
    NOT strip TP — only fsdp=True opts a config into the ZeRO-3 profile."""
    cfg = configs.get_config("granite_3_2b")      # profile "fsdp", fsdp=False
    assert cfg.sharding_profile == "fsdp" and not cfg.fsdp
    rules = default_rules(MESH, cfg)
    assert rules.rules["mlp"] == "model"          # TP kept
    assert rules.rules["vocab"] == "model"
    assert rules.rules["embed"] is None           # no FSDP param sharding
    # with the opt-in, the same config takes the full ZeRO-3 profile
    import dataclasses
    cfg2 = dataclasses.replace(cfg, fsdp=True)
    rules2 = default_rules(MESH, cfg2)
    assert rules2.rules["embed"] == ("data", "model")
    assert rules2.rules["mlp"] is None
    # serving never takes the train-only ZeRO profile
    rules3 = default_rules(MESH, cfg2, serve=True)
    assert rules3.rules["mlp"] == "model"


def test_fsdp_rules_direct():
    """_fsdp_rules unit contract: params + batch over (data, model) jointly,
    no TP anywhere, pod left as pure gradient-replica DP."""
    cfg = configs.get_config("deepseek_67b")
    rules = _fsdp_rules(MESH, cfg)
    assert rules.rules["batch"] == ("data", "model")
    assert rules.rules["embed"] == ("data", "model")
    assert rules.rules["moe_groups"] == ("data", "model")
    for name in ("heads", "kv_heads", "mlp", "vocab", "experts", "rnn",
                 "q_proj", "kv_proj", "kv_cache_seq", "seq"):
        assert rules.rules[name] is None, name
    # pod axis untouched on the 3-axis mesh (pure replica DP)
    rules3 = _fsdp_rules(MESH3, cfg)
    assert rules3.rules["batch"] == ("data", "model")
    # divisibility fallback still applies: embed dim not divisible by 256
    assert rules.spec_for(("embed", "mlp"), (100, 22016)) == P(None, None)
    # one-axis mesh degrades to a scalar axis entry
    mesh1 = fake_mesh((8,), ("data",))
    assert _fsdp_rules(mesh1, cfg).rules["embed"] == "data"


def test_vocab_pad_for():
    assert vocab_pad_for(MESH) == 16
    assert vocab_pad_for(MESH3) == 16
    assert vocab_pad_for(fake_mesh((8,), ("data",))) == 1  # no model axis


def test_all_archs_build_rules_on_both_meshes():
    for name in configs.ARCHS:
        cfg = configs.get_config(name)
        for mesh in (MESH, MESH3):
            rules = default_rules(mesh, cfg)
            assert "batch" in rules.rules
