"""Speculative decoding: drafter/acceptance properties + composition matrix.

The load-bearing contracts:
* the prompt-lookup drafter is deterministic, draws proposals from its own
  history (always in-vocab), and matches a brute-force oracle of its spec
  (longest n-gram first, most recent earlier match wins);
* the greedy acceptance rule emits exactly what step-by-step greedy decode
  would — fuzzed against a sequential oracle, including the k=0 degeneracy;
* multi-token ``kv_len`` advances are safe: ``prepare_write(n)`` grows and
  copy-on-writes every block a verify write touches (crossing page
  boundaries), partial acceptance (the logical rollback) never leaks or
  double-allocates pages, and a near-dry pool preempts mid-growth with the
  already-granted pages conserved;
* the composition matrix: the speculative engine is BIT-IDENTICAL to the
  plain greedy engine — and to the contiguous-cache reference — across
  {eager, lazy + forced preemption, prefix sharing + COW, chunked prefill,
  sliding window + reclamation, num_splits > 1}; a slow-tier case repeats
  it on a 2-way sharded mesh in a subprocess with fake CPU devices;
* an oracle drafter with perfect foresight drives acceptance to 1.0, so the
  multi-token acceptance path (page-boundary-crossing advances, fewer
  verify steps) demonstrably runs — not just the 1-token fallback.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (NgramDrafter, PagedCacheConfig, Request, Scheduler,
                           ServingEngine, longest_accept)
from repro.serving.paged_cache import BlockTables

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# drafter: unit + fuzz vs a brute-force oracle
# ---------------------------------------------------------------------------

def test_drafter_basic_lookup():
    d = NgramDrafter(k=3, max_ngram=2, min_ngram=1)
    # trailing [4, 5] recurs at position 1; the continuation is [6, 7, 8]
    hist = [9, 4, 5, 6, 7, 8, 4, 5]
    assert list(d.propose(np.asarray(hist))) == [6, 7, 8]
    # max_tokens caps the proposal below k
    assert list(d.propose(np.asarray(hist), max_tokens=2)) == [6, 7]
    assert list(d.propose(np.asarray(hist), max_tokens=0)) == []
    # no recurrence anywhere → nothing proposed
    assert list(d.propose(np.asarray([1, 2, 3, 4]))) == []


def test_drafter_prefers_longer_then_most_recent():
    d = NgramDrafter(k=2, max_ngram=3, min_ngram=1)
    # trailing 3-gram [1, 2, 3] matches at position 0 even though the
    # trailing 1-gram [3] also matches later — the longer match wins
    hist = [1, 2, 3, 7, 3, 8, 1, 2, 3]
    assert list(d.propose(np.asarray(hist))) == [7, 3]
    # two occurrences of the trailing 1-gram: the most recent wins
    d1 = NgramDrafter(k=1, max_ngram=1)
    assert list(d1.propose(np.asarray([5, 1, 5, 2, 5]))) == [2]


def test_drafter_validation():
    with pytest.raises(ValueError):
        NgramDrafter(k=0)
    with pytest.raises(ValueError):
        NgramDrafter(k=2, max_ngram=1, min_ngram=2)
    with pytest.raises(ValueError):
        NgramDrafter(k=2, min_ngram=0)


def _oracle_propose(hist, k, max_ngram, min_ngram, limit):
    """Brute-force re-statement of the drafter spec."""
    n_hist = len(hist)
    limit = min(k, limit)
    if limit < 1 or n_hist < min_ngram + 1:
        return []
    for n in range(min(max_ngram, n_hist - 1), min_ngram - 1, -1):
        tail = hist[n_hist - n:]
        for i in range(n_hist - 1 - n, -1, -1):   # most recent first
            if hist[i:i + n] == tail:
                return hist[i + n:i + n + limit]
    return []


def test_drafter_fuzz_matches_oracle():
    """Seeded fuzz: random small-vocab histories (repetition-rich) checked
    against the brute-force oracle; proposals are deterministic, length- and
    vocab-bounded by construction."""
    rs = np.random.RandomState(11)
    for _ in range(300):
        k = int(rs.randint(1, 6))
        max_n = int(rs.randint(1, 5))
        min_n = int(rs.randint(1, max_n + 1))
        d = NgramDrafter(k, max_ngram=max_n, min_ngram=min_n)
        hist = rs.randint(0, 4, size=rs.randint(0, 24)).astype(np.int32)
        limit = int(rs.randint(0, k + 2))
        got = d.propose(hist, max_tokens=limit)
        assert list(got) == _oracle_propose(
            list(map(int, hist)), k, max_n, min_n, limit)
        assert list(got) == list(d.propose(hist, max_tokens=limit))  # det.
        assert len(got) <= min(k, limit)
        assert all(t in set(map(int, hist)) for t in got)            # in-vocab


# ---------------------------------------------------------------------------
# acceptance rule: explicit cases + fuzz vs a sequential-decode oracle
# ---------------------------------------------------------------------------

def test_longest_accept_cases():
    # full acceptance: every draft survives, plus the bonus token
    assert longest_accept([1, 2], [1, 2, 9]) == (2, [1, 2, 9])
    # first mismatch: accepted prefix + the model's own token there
    assert longest_accept([1, 2], [1, 7, 9]) == (1, [1, 7])
    assert longest_accept([1, 2], [5, 7, 9]) == (0, [5])
    # k = 0 degenerates to exactly one plain decode step
    assert longest_accept([], [3]) == (0, [3])
    with pytest.raises(AssertionError):
        longest_accept([1, 2], [1, 2])           # must score k+1 positions


def test_longest_accept_fuzz_equals_sequential_decode():
    """Oracle re-check: fix an arbitrary deterministic "model" next-token
    function; however the draft was produced, the emitted tokens must equal
    what stepwise greedy decode produces, and the un-emitted suffix is
    exactly the rejected (rolled-back) region."""
    rs = np.random.RandomState(5)
    for _ in range(300):
        k = int(rs.randint(0, 6))
        ctx = list(map(int, rs.randint(0, 7, size=rs.randint(1, 5))))

        def model_next(seq, _s=int(rs.randint(1 << 30))):
            return (hash((_s,) + tuple(seq)) % 7)

        draft = [int(t) for t in rs.randint(0, 7, size=k)]
        if k and rs.rand() < 0.7:      # often feed partially-correct drafts
            good = []
            s = list(ctx)
            for _ in range(k):
                good.append(model_next(s))
                s.append(good[-1])
            cut = int(rs.randint(0, k + 1))
            draft = good[:cut] + draft[cut:]
        # the verify pass scores position j given ctx + draft[:j]
        greedy = []
        for j in range(k + 1):
            greedy.append(model_next(ctx + draft[:j]))
        accepted, emitted = longest_accept(draft, greedy)
        # sequential oracle: decode len(emitted) tokens one at a time
        s = list(ctx)
        for tok in emitted:
            assert model_next(s) == tok
            s.append(tok)
        assert 0 <= accepted <= k and len(emitted) == accepted + 1
        # the token after the accepted prefix must NOT match (else the rule
        # under-accepted)
        if accepted < k:
            assert draft[accepted] != greedy[accepted]


# ---------------------------------------------------------------------------
# multi-token growth: page boundaries, COW, rollback, near-dry preemption
# ---------------------------------------------------------------------------

def test_prepare_write_spans_page_boundaries():
    cfg = PagedCacheConfig(page_size=4, num_pages=10, max_batch=2,
                           max_pages_per_seq=5)
    t = BlockTables(cfg)
    assert t.admit(0, 6)                       # blocks 0, 1 owned
    t.kv_len[0] = 6
    g0 = t.pages_grown
    assert t.prepare_write(0, 5)               # positions 6..10 → blocks 1, 2
    assert t.pages_grown == g0 + 1             # only block 2 is new
    assert t.append_dest_ok(0, 5)
    dest = t.span_dest(0, 6, 11)
    for i, p in enumerate(range(6, 11)):       # scatter math page-exact
        assert dest[i] == t.tables[0, p // 4] * 4 + p % 4
    # partial acceptance (logical rollback): only 2 of 5 writes advance;
    # re-preparing the shifted span grows exactly the one new block and
    # never re-allocates the already-owned ones
    t.kv_len[0] = 8
    g1 = t.pages_grown
    assert t.prepare_write(0, 5)               # positions 8..12 → blocks 2, 3
    assert t.pages_grown == g1 + 1
    assert t.prepare_write(0, 5)               # idempotent
    assert t.pages_grown == g1 + 1
    # a span escaping the block table raises rather than corrupting
    t.kv_len[0] = 18
    with pytest.raises(ValueError):
        t.prepare_write(0, 5)                  # position 20 → block 5 of 5


def test_prepare_write_multi_block_cow():
    """A verify span crossing from a prefix-shared block into an append
    block must COW the shared page AND grow the append page in one call —
    rejected draft writes may land in either, and neither may touch a page
    another sequence still reads."""
    cfg = PagedCacheConfig(page_size=4, num_pages=12, max_batch=2,
                           max_pages_per_seq=4)
    t = BlockTables(cfg, share_prefix=True)
    prompt = np.arange(8, dtype=np.int32)
    assert t.admit(0, 8, tokens=prompt)
    t.kv_len[0] = 8
    t.register_prefilled(0, 8)
    assert t.admit(1, 8, tokens=prompt)        # aliases both prompt blocks
    assert t.pages_shared == 2
    shared_pg = int(t.tables[1, 1])
    assert shared_pg == int(t.tables[0, 1])
    assert t.allocator.refcount(shared_pg) == 2
    # slot 1 re-runs its last prompt token then speculates: positions 7..11
    # span shared block 1 and fresh blocks 2 (COW + grow in one call)
    t.kv_len[1] = 7
    assert t.prepare_write(1, 5)
    assert t.cow_copies == 1
    fresh = int(t.tables[1, 1])
    assert fresh != shared_pg
    assert t.allocator.refcount(shared_pg) == 1    # slot 0 keeps the page
    assert t.drain_copies() == [(shared_pg, fresh)]
    assert t.append_dest_ok(1, 5)
    # the scatter slots for the spanned positions hit the fresh pages only
    dest = t.span_dest(1, 7, 12)
    assert dest[0] == fresh * 4 + 3
    assert shared_pg not in set(int(x) // 4 for x in dest)


def test_ensure_growth_near_dry_pool_preempts_mid_growth():
    """A multi-page lookahead that runs the pool dry *between* the blocks of
    one span: the first block is granted, the second finds the pool empty,
    the youngest row is preempted, and the retried grant completes — with
    the partially-granted page conserved throughout (never leaked, never
    double-allocated)."""
    cfg = PagedCacheConfig(page_size=4, num_pages=4, max_batch=2,
                           max_pages_per_seq=3)     # 3 usable pages
    sched = Scheduler(cfg, lazy=True)
    alloc = sched.tables.allocator
    for rid, gen in ((0, 8), (1, 8)):
        sched.submit(Request(rid=rid, tokens=np.arange(4, dtype=np.int32),
                             max_new_tokens=gen))
    admitted = sched.admit()                        # 1 prompt page each
    assert len(admitted) == 2 and alloc.num_free == 1
    for seq in admitted:                            # emulate the prefill
        seq.prefilled = 4
        sched.tables.kv_len[seq.slot] = 4
        sched.tables.register_prefilled(seq.slot, 4)
        seq.generated.append(1)
    old, young = sorted(admitted, key=lambda s: s.birth)
    # lookahead 5 → positions 4..8 → blocks 1 and 2 for the oldest row:
    # block 1 takes the last free page, block 2 preempts the youngest
    preempted = sched.ensure_growth(5)
    assert preempted == [young.request.rid]
    assert sched.preemptions == 1
    assert sorted(sched.tables._owned[old.slot]) == [0, 1, 2]
    assert sched.tables.append_dest_ok(old.slot, 5)
    # conservation: 3 pages on the oldest row, none free, none leaked
    assert alloc.num_free == 0
    assert alloc.num_allocated == 3
    assert alloc.refs_total == 3
    # the preempted row is queued at the front with its token re-folded
    assert sched.waiting[0].rid == young.request.rid
    assert sched.waiting[0].prompt_len == 5
    # the oldest finishing returns everything — the resumed row can admit
    old.generated.extend([1] * 7)
    sched.evict_finished()
    assert alloc.num_free == 3
    assert len(sched.admit()) == 1


def test_self_preemption_frees_partial_multi_block_grant():
    """The youngest row dries the pool between the blocks of its own span:
    it self-preempts, and the block it *did* get granted mid-span returns
    to the pool with the rest (no leak)."""
    cfg = PagedCacheConfig(page_size=2, num_pages=6, max_batch=2,
                           max_pages_per_seq=4)     # 5 usable pages
    sched = Scheduler(cfg, lazy=True)
    alloc = sched.tables.allocator
    for rid in (0, 1):
        sched.submit(Request(rid=rid, tokens=np.arange(2, dtype=np.int32),
                             max_new_tokens=6))     # budget 8 = 4 pages
    admitted = sched.admit()                        # 1 prompt page each
    assert len(admitted) == 2 and alloc.num_free == 3
    for seq in admitted:
        seq.prefilled = 2
        sched.tables.kv_len[seq.slot] = 2
        sched.tables.register_prefilled(seq.slot, 2)
        seq.generated.append(1)
    old, young = sorted(admitted, key=lambda s: s.birth)
    # lookahead 3 → positions 2..4 → blocks 1, 2 (two pages per row).  The
    # oldest takes two of the three free pages; the youngest grants block 1
    # with the last one, dries at block 2 and self-preempts — the partial
    # grant must free along with its prompt page
    preempted = sched.ensure_growth(3)
    assert preempted == [young.request.rid]
    assert sched.preemptions == 1
    assert list(sched.active) == [old.slot]
    assert sched.tables.append_dest_ok(old.slot, 3)
    assert alloc.num_free == 2                      # young's 2 pages back
    assert alloc.num_allocated == 3                 # old: blocks 0, 1, 2
    assert alloc.refs_total == 3
    assert sched.waiting[0].rid == young.request.rid
    assert sched.waiting[0].prompt_len == 3         # generated folded in


# ---------------------------------------------------------------------------
# the composition matrix: spec ≡ plain greedy across every serving feature
# ---------------------------------------------------------------------------

def _smoke_cfg():
    from repro import configs
    return dataclasses.replace(configs.smoke_config("qwen3_14b"),
                               dtype=jnp.float32, remat=False)


def _params(cfg):
    from repro.models import lm
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    return params


def _motif_reqs(rs, vocab, specs):
    """Ragged requests whose prompts tile a short motif, so the n-gram
    drafter has recurrences to match (uniform-random prompts rarely draft)."""
    reqs = []
    for plen, gen in specs:
        motif = rs.randint(0, vocab, size=4)
        reqs.append((np.tile(motif, -(-plen // 4))[:plen].astype(np.int32),
                     gen))
    return reqs


def _run_pair(cfg, pcfg, params, reqs, k=4, **kw):
    """Run the same workload plain and speculative; return both."""
    outs, stats = [], []
    for spec in (None, k):
        eng = ServingEngine(cfg, pcfg, params, impl="xla", xla_chunk=16,
                            speculate_k=spec, **kw)
        o, s = eng.run(list(reqs))
        assert eng.scheduler.tables.allocator.num_free \
            + eng.scheduler.tables.allocator.num_cached == pcfg.usable_pages
        outs.append(o)
        stats.append(s)
    assert set(outs[0]) == set(outs[1])
    for rid in outs[0]:
        assert np.array_equal(outs[0][rid], outs[1][rid]), \
            f"request {rid}: spec {outs[1][rid]} != plain {outs[0][rid]}"
    return outs[0], stats[0], stats[1]


BASE_SPECS = [(9, 6), (5, 8), (8, 4)]


def test_spec_matrix_eager_matches_plain_and_contiguous():
    """Eager cell, plus the contiguous anchor: the speculative paged engine
    reproduces the contiguous-cache single-request reference token for
    token (transitively pinning every later cell to the same reference)."""
    from repro.runtime.steps import make_serve_steps

    cfg = _smoke_cfg()
    params = _params(cfg)
    reqs = _motif_reqs(np.random.RandomState(0), cfg.vocab_size, BASE_SPECS)

    def contiguous_gen(prompt, max_new, max_len=16):
        arts = make_serve_steps(cfg, impl="xla", max_len=max_len, batch=1,
                                xla_chunk=16)
        caches = arts.cache_init_fn()
        logits, caches = arts.prefill_fn(params, jnp.asarray(prompt)[None],
                                         None, caches)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        out = [int(tok[0])]
        for i in range(max_new - 1):
            logits, caches = arts.decode_fn(params, tok, caches,
                                            jnp.int32(len(prompt) + i))
            tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
            out.append(int(tok[0]))
        return np.asarray(out, np.int32)

    pcfg = PagedCacheConfig(page_size=4, num_pages=14, max_batch=2,
                            max_pages_per_seq=4)
    out, st_plain, st_spec = _run_pair(cfg, pcfg, params, reqs,
                                       prefill_len=16)
    for rid, (prompt, gen) in enumerate(reqs):
        exp = contiguous_gen(prompt, gen)
        assert np.array_equal(out[rid], exp), \
            f"request {rid}: paged {out[rid]} != contiguous {exp}"
    assert st_spec["drafted_tokens"] > 0         # the drafter actually fired
    assert st_spec["decode_steps"] <= st_plain["decode_steps"]
    # budgets hold exactly under multi-token emission
    for rid, (_, gen) in enumerate(reqs):
        assert len(out[rid]) == gen


def test_spec_matrix_lazy_forced_preemption():
    """Lazy cell: a pool tight enough that the spec run's multi-page
    lookahead growth preempts — preempt/re-prefill must compose with
    drafting (the resumed history re-folds generated into the prompt)."""
    cfg = _smoke_cfg()
    params = _params(cfg)
    reqs = _motif_reqs(np.random.RandomState(1), cfg.vocab_size, BASE_SPECS)
    pcfg = PagedCacheConfig(page_size=4, num_pages=7, max_batch=2,
                            max_pages_per_seq=4)
    _, st_plain, st_spec = _run_pair(cfg, pcfg, params, reqs,
                                     prefill_len=16, lazy=True)
    assert st_spec["preemptions"] >= 1           # the pressure actually bit
    assert st_spec["pages_grown"] >= 1


def test_spec_matrix_prefix_sharing_cow():
    """Prefix-sharing cell: an identical late prompt aliases a live row's
    registered pages, so the verify write must COW before scattering —
    rejected drafts never corrupt the sibling's KV."""
    cfg = _smoke_cfg()
    params = _params(cfg)
    rs = np.random.RandomState(2)
    motif = rs.randint(0, cfg.vocab_size, size=4)
    shared = np.tile(motif, 2).astype(np.int32)            # 8 = 2 full blocks
    other = rs.randint(0, cfg.vocab_size, size=5).astype(np.int32)
    # the twin prompt admits while the first is still decoding (the short
    # middle request frees its slot early) → live aliasing, then COW
    reqs = [(shared, 8), (other, 2), (shared.copy(), 4)]
    pcfg = PagedCacheConfig(page_size=4, num_pages=14, max_batch=2,
                            max_pages_per_seq=4)
    _, st_plain, st_spec = _run_pair(cfg, pcfg, params, reqs,
                                     prefill_len=16, share_prefix=True)
    assert st_spec["pages_shared"] > 0
    assert st_spec["cow_copies"] >= 1


def test_spec_matrix_chunked_prefill():
    """Chunked-prefill cell: drafts interleave with mid-prompt rows riding
    the verify step masked (trash tables / kv_len 0)."""
    cfg = _smoke_cfg()
    params = _params(cfg)
    reqs = _motif_reqs(np.random.RandomState(3), cfg.vocab_size, BASE_SPECS)
    pcfg = PagedCacheConfig(page_size=4, num_pages=14, max_batch=2,
                            max_pages_per_seq=4)
    _, _, st_spec = _run_pair(cfg, pcfg, params, reqs,
                              prefill_len=16, prefill_chunk=5)
    assert st_spec["prefill_tokens"] == sum(len(p) for p, _ in reqs)


def test_spec_matrix_sliding_window_reclamation():
    """Sliding-window cell: multi-token advances cross reclamation horizons;
    the freed-page gate must hold for every drafted position."""
    cfg = dataclasses.replace(_smoke_cfg(), attn_window=10)
    params = _params(cfg)
    reqs = _motif_reqs(np.random.RandomState(4), cfg.vocab_size,
                       [(8, 12), (11, 9)])
    pcfg = PagedCacheConfig(page_size=4, num_pages=10, max_batch=2,
                            max_pages_per_seq=6)
    _, _, st_spec = _run_pair(cfg, pcfg, params, reqs,
                              prefill_len=24, lazy=True)
    assert st_spec["pages_reclaimed"] > 0


def test_spec_matrix_split_kv_decode():
    """num_splits > 1 cell: the verify step inherits the decode path's
    split-KV launch geometry; partial-merge must stay exact across the
    k+1-wide token axis."""
    cfg = _smoke_cfg()
    params = _params(cfg)
    reqs = _motif_reqs(np.random.RandomState(6), cfg.vocab_size, BASE_SPECS)
    pcfg = PagedCacheConfig(page_size=4, num_pages=14, max_batch=2,
                            max_pages_per_seq=4)
    _run_pair(cfg, pcfg, params, reqs, prefill_len=16, num_splits=2)


# ---------------------------------------------------------------------------
# oracle drafter: force multi-token acceptance end to end
# ---------------------------------------------------------------------------

class _OracleDrafter:
    """Perfect-foresight drafter: proposes the continuation of whichever
    reference stream (prompt + the plain run's generation) the row's history
    is a prefix of.  Drives acceptance to 1.0, so multi-token kv_len
    advances — page-boundary crossings included — provably execute."""

    def __init__(self, k, streams):
        self.k = k
        self.streams = [np.asarray(s, np.int32) for s in streams]

    def propose(self, history, max_tokens=-1):
        limit = self.k if max_tokens < 0 else min(self.k, max_tokens)
        h = np.asarray(history, np.int32)
        n = int(h.shape[0])
        if limit < 1:
            return np.zeros(0, np.int32)
        for s in self.streams:
            if s.shape[0] >= n and np.array_equal(s[:n], h):
                return s[n:n + limit].copy()
        return np.zeros(0, np.int32)


def test_oracle_drafter_full_acceptance_advances_multi_token():
    cfg = _smoke_cfg()
    params = _params(cfg)
    rs = np.random.RandomState(0)
    reqs = [(rs.randint(0, cfg.vocab_size, size=L).astype(np.int32), g)
            for L, g in BASE_SPECS]
    pcfg = PagedCacheConfig(page_size=4, num_pages=14, max_batch=2,
                            max_pages_per_seq=4)

    eng_p = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=16,
                          xla_chunk=16)
    out_p, st_p = eng_p.run(list(reqs))
    streams = [np.concatenate([reqs[rid][0], out_p[rid]])
               for rid in sorted(out_p)]

    eng_s = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=16,
                          xla_chunk=16, speculate_k=4)
    eng_s.drafter = _OracleDrafter(4, streams)
    out_s, st_s = eng_s.run(list(reqs))
    for rid in out_p:
        assert np.array_equal(out_s[rid], out_p[rid])
    assert st_s["acceptance_rate"] == 1.0
    assert st_s["accepted_tokens"] > 0
    # 5-token advances over page_size=4 pages force boundary crossings, and
    # the verify-step count collapses accordingly
    assert st_s["decode_steps"] * 2 < st_p["decode_steps"]


def test_oracle_drafter_under_lazy_preemption():
    """Full-width accepted spans under a dry pool: multi-page growth,
    preemption mid-workload, and re-prefilled rows whose oracle stream still
    matches after the generated tokens fold into the prompt."""
    cfg = _smoke_cfg()
    params = _params(cfg)
    rs = np.random.RandomState(1)
    reqs = [(rs.randint(0, cfg.vocab_size, size=L).astype(np.int32), g)
            for L, g in BASE_SPECS]
    pcfg = PagedCacheConfig(page_size=4, num_pages=7, max_batch=2,
                            max_pages_per_seq=4)

    eng_p = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=16,
                          xla_chunk=16, lazy=True)
    out_p, st_p = eng_p.run(list(reqs))
    streams = [np.concatenate([reqs[rid][0], out_p[rid]])
               for rid in sorted(out_p)]

    eng_s = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=16,
                          xla_chunk=16, lazy=True, speculate_k=4)
    eng_s.drafter = _OracleDrafter(4, streams)
    out_s, st_s = eng_s.run(list(reqs))
    for rid in out_p:
        assert np.array_equal(out_s[rid], out_p[rid])
    assert st_s["preemptions"] >= 1
    assert st_s["accepted_tokens"] > 0


def test_spec_eos_mid_accepted_draft():
    """EOS landing inside an accepted span: the emission truncates at the
    EOS inclusive — tokens past it (already scattered into pages) are
    discarded with the evicted row, identical to plain EOS eviction."""
    cfg = _smoke_cfg()
    params = _params(cfg)
    rs = np.random.RandomState(2)
    prompt = rs.randint(0, cfg.vocab_size, size=8).astype(np.int32)
    pcfg = PagedCacheConfig(page_size=4, num_pages=14, max_batch=2,
                            max_pages_per_seq=4)

    eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=16,
                        xla_chunk=16)
    ref, _ = eng.run([(prompt, 8)])
    ref = ref[0]
    eos = int(ref[4])                        # truncate mid-generation
    cut = list(ref).index(eos) + 1

    def run(spec):
        eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=16,
                            xla_chunk=16, eos_id=eos, speculate_k=spec)
        if spec:
            eng.drafter = _OracleDrafter(
                spec, [np.concatenate([prompt, ref])])
        out, st = eng.run([(prompt, 8)])
        return out[0], st

    out_plain, _ = run(None)
    out_spec, st_spec = run(4)
    assert list(out_plain) == list(ref[:cut])
    assert list(out_spec) == list(out_plain)
    assert st_spec["decode_steps"] < len(out_plain)   # multi-token emission


def test_speculate_validation():
    cfg = _smoke_cfg()
    params = _params(cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_batch=2,
                            max_pages_per_seq=4)
    with pytest.raises(ValueError):
        ServingEngine(cfg, pcfg, params, speculate_k=-1)
    # 0 and None mean off: no drafter, single-token lookahead
    eng = ServingEngine(cfg, pcfg, params, speculate_k=0)
    assert eng.drafter is None and eng._lookahead == 1


# ---------------------------------------------------------------------------
# distributed: sharded speculative engine ≡ single-device plain engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_spec_engine_matches_single_device():
    """The matrix's sharded cell: speculative decoding on a 2-way ("model",)
    mesh — verify runs the per-shard partial-merge decode path k+1 tokens
    wide — reproduces the single-device PLAIN engine token for token.
    Subprocess: the fake-device XLA flag must be set before jax initialises."""
    code = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.serving import PagedCacheConfig, ServingEngine

cfg = dataclasses.replace(configs.smoke_config("qwen3_14b"),
                          dtype=jnp.float32, remat=False)
params, _ = lm.init_params(cfg, jax.random.PRNGKey(0), vocab_pad_to=2)
rs = np.random.RandomState(0)
reqs = []
for plen, gen in [(9, 6), (5, 8), (8, 4)]:
    motif = rs.randint(0, cfg.vocab_size, size=4)
    reqs.append((np.tile(motif, -(-plen // 4))[:plen].astype(np.int32), gen))

pcfg = PagedCacheConfig(page_size=4, num_pages=14, max_batch=2,
                        max_pages_per_seq=4)
eng1 = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=16,
                     xla_chunk=16)
out1, _ = eng1.run(list(reqs))

mesh = make_mesh((2,), ("model",))
pcfg2 = dataclasses.replace(pcfg, num_shards=2)
eng2 = ServingEngine(cfg, pcfg2, params, impl="xla", prefill_len=16,
                     xla_chunk=16, mesh=mesh, speculate_k=4)
out2, stats2 = eng2.run(list(reqs))

assert set(out1) == set(out2)
for rid in out1:
    assert np.array_equal(out1[rid], out2[rid]), \\
        f"request {rid}: sharded-spec {out2[rid]} != plain {out1[rid]}"
assert stats2["drafted_tokens"] > 0
assert eng2.scheduler.tables.allocator.num_free == pcfg2.usable_pages
print("PASS")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=env, capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    assert "PASS" in out.stdout
