"""Edge-case coverage across the four interchangeable attention impls.

Each case runs against the naive f32 reference: non-block-multiple padded
tails, fully-masked rows (the l==0 finalize path in flash_fwd), and GQA with
hq != hkv — forward AND gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_qkv, max_err
from repro.core.attention import spark_attention
from repro.kernels.flash_fwd import flash_fwd
from repro.kernels.flash_bwd import flash_bwd
from repro.kernels.ref import naive_mha

IMPLS = ("naive", "xla", "pallas_interpret")

# non-block-multiple tails and ragged GQA geometries:
# b, hq, hkv, sq, skv, d, causal, window, bq, bkv
TAIL_CASES = [
    (1, 2, 2, 100, 100, 32, True, None, 64, 64),    # both dims padded
    (1, 2, 2, 65, 65, 32, True, None, 64, 64),      # 1-token tail
    (1, 4, 1, 72, 136, 32, True, None, 32, 64),     # suffix query + MQA + pad
    (2, 6, 2, 96, 96, 32, True, 40, 32, 32),        # GQA group 3 + window
    (1, 8, 2, 60, 60, 32, False, None, 64, 64),     # non-causal GQA, sub-block
]


# real kernel bodies on every case; the xla scan samples two (its masking code
# path is shared across cases and fully swept in test_kernel_fwd)
FWD_MATRIX = ([("pallas_interpret", c) for c in TAIL_CASES] +
              [("xla", TAIL_CASES[2]), ("xla", TAIL_CASES[3])])


@pytest.mark.parametrize("impl,case", FWD_MATRIX,
                         ids=[f"{i}-{c}" for i, c in FWD_MATRIX])
def test_padded_tails_and_gqa_fwd(rng_key, impl, case):
    b, hq, hkv, sq, skv, d, causal, window, bq, bkv = case
    q, k, v, _ = make_qkv(rng_key, b, hq, hkv, sq, skv, d)
    o = spark_attention(q, k, v, impl=impl, causal=causal, window=window,
                        block_q=bq, block_kv=bkv, xla_chunk=bkv)
    o_ref = spark_attention(q, k, v, impl="naive", causal=causal,
                            window=window)
    assert o.shape == (b, hq, sq, d)
    assert max_err(o, o_ref) < 1e-3


@pytest.mark.parametrize("impl", ("xla", "pallas_interpret"))
@pytest.mark.parametrize("case", (TAIL_CASES[1], TAIL_CASES[2]),
                         ids=[str(TAIL_CASES[1]), str(TAIL_CASES[2])])
def test_padded_tails_and_gqa_grads(rng_key, impl, case):
    b, hq, hkv, sq, skv, d, causal, window, bq, bkv = case
    q, k, v, do = make_qkv(rng_key, b, hq, hkv, sq, skv, d)

    def loss(impl_):
        def f(q, k, v):
            o = spark_attention(q, k, v, impl=impl_, causal=causal,
                                window=window, block_q=bq, block_kv=bkv,
                                xla_chunk=bkv)
            return (o * do).sum()
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    g_ref = loss("naive")
    g = loss(impl)
    for a, r in zip(g, g_ref):
        assert a.shape == r.shape
        assert max_err(a, r) < 1e-3


@pytest.mark.parametrize("impl", IMPLS)
def test_fully_masked_rows_emit_zeros(rng_key, impl):
    """causal + window=0 leaves every row with no visible key: every impl must
    emit exact zeros (flash_fwd's l==0 finalize path), never NaN or a uniform
    average over V."""
    q, k, v, _ = make_qkv(rng_key, 1, 2, 2, 64, 64, 32)
    o = spark_attention(q, k, v, impl=impl, causal=True, window=0,
                        block_q=32, block_kv=32, xla_chunk=32)
    o = np.asarray(o)
    assert not np.isnan(o).any()
    assert np.abs(o).max() == 0.0


def test_fully_masked_rows_lse_and_grads(rng_key):
    """The kernel's lse for a fully-masked row is NEG_INF (not NaN) and the
    dual-pass backward produces exactly zero gradients through those rows."""
    from repro.core.online_softmax import NEG_INF
    q, k, v, do = make_qkv(rng_key, 1, 2, 2, 64, 64, 32)
    o, lse = flash_fwd(q, k, v, causal=True, window=0, block_q=32, block_kv=32,
                       interpret=True)
    assert not bool(jnp.isnan(lse).any())
    assert float(jnp.abs(o).max()) == 0.0
    assert bool(jnp.all(lse == NEG_INF))
    dq, dk, dv = flash_bwd(q, k, v, o, lse, do, causal=True, window=0,
                           block_q=32, block_kv=32, interpret=True)
    for g in (dq, dk, dv):
        assert not bool(jnp.isnan(g).any())
        assert float(jnp.abs(g).max()) == 0.0


def test_partially_masked_block_recovers(rng_key):
    """A row whose FIRST kv blocks are fully masked must still be exact once a
    visible block arrives (the online-softmax rescale zeroes the transient)."""
    b, h, s, d = 1, 2, 128, 32
    q, k, v, _ = make_qkv(rng_key, b, h, h, s, s, d)
    # window 16 over 32-wide kv blocks: for late rows the early blocks are
    # entirely invisible, and block-skip drops most of them.
    o, _ = flash_fwd(q, k, v, causal=True, window=16, block_q=32, block_kv=32,
                     interpret=True)
    o_ref = naive_mha(q, k, v, causal=True, window=16)
    assert max_err(o, o_ref) < 1e-3


def test_single_token_sequences(rng_key):
    """sq == skv == 1: the most degenerate shape must still normalise."""
    q, k, v, _ = make_qkv(rng_key, 2, 2, 2, 1, 1, 32)
    for impl in IMPLS:
        o = spark_attention(q, k, v, impl=impl, causal=True,
                            block_q=8, block_kv=8, xla_chunk=8)
        # softmax over one visible key == that key's value row
        assert max_err(o, jnp.broadcast_to(v, o.shape)) < 1e-5
