"""Distributed semantics tests, run in subprocesses with fake CPU devices
(XLA_FLAGS device-count must be set before jax initialises)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

# each test forks a fresh interpreter that re-imports jax with 8 fake devices
# (~5-60s apiece) — slow tier; run with `pytest -m slow`
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


COMMON = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch.mesh import make_mesh
from repro.runtime.steps import make_train_step
from repro.data import DataConfig, make_batch
from repro.optim import AdamWConfig

def tiny_cfg():
    return dataclasses.replace(configs.smoke_config("granite_3_2b"),
                               dtype=jnp.float32, num_layers=2, d_model=32,
                               num_heads=4, num_kv_heads=2, d_ff=64,
                               vocab_size=64)
"""


def test_sharded_grads_match_single_device():
    """(2,4)-mesh training step ≡ single-device step (same batch, same init)."""
    out = run_sub(COMMON + """
cfg = tiny_cfg()
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
batch = {k: jnp.asarray(v) for k, v in make_batch(dc, 0).items()}

arts0 = make_train_step(cfg, opt=AdamWConfig(lr=1e-2), impl="xla",
                        xla_chunk=32, donate=False)
p0, o0, _ = arts0.init_fn(jax.random.PRNGKey(0))
p0n, _, m0 = arts0.step_fn(p0, o0, batch, jnp.int32(0))

mesh = make_mesh((2, 4), ("data", "model"))
arts1 = make_train_step(cfg, mesh=mesh, opt=AdamWConfig(lr=1e-2), impl="xla",
                        xla_chunk=32, donate=False)
p1, o1, _ = arts1.init_fn(jax.random.PRNGKey(0))
p1 = jax.device_put(p1, arts1.shardings["params"])
o1 = jax.device_put(o1, arts1.shardings["opt"])
p1n, _, m1 = arts1.step_fn(p1, o1, batch, jnp.int32(0))

err_loss = abs(float(m0["loss"]) - float(m1["loss"]))
errs = [float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(jax.tree.leaves(p0n), jax.tree.leaves(p1n))]
print("loss_err", err_loss, "param_err", max(errs))
assert err_loss < 1e-5 and max(errs) < 1e-5
print("PASS")
""")
    assert "PASS" in out


def test_elastic_reshard_resume():
    """Train on a (4,2) mesh, checkpoint, resume on (2,2) with half the
    devices — loss trajectory must continue identically (mesh-agnostic ckpt)."""
    out = run_sub(COMMON + """
import tempfile
from repro.runtime.trainer import Trainer, TrainerConfig
tmp = tempfile.mkdtemp()
cfg = tiny_cfg()
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)

def build(mesh_shape):
    mesh = make_mesh(mesh_shape, ("data", "model"))
    arts = make_train_step(cfg, mesh=mesh, opt=AdamWConfig(lr=1e-3),
                           impl="xla", xla_chunk=32, donate=False)
    tcfg = TrainerConfig(ckpt_dir=tmp, ckpt_every=3, log_every=1000,
                         async_ckpt=False)
    return Trainer(arts=arts, data_cfg=dc, tcfg=tcfg,
                   batch_shardings=None)

t1 = build((4, 2))
t1.run(6)           # checkpoints at steps 2 and 5
t2 = build((2, 2))  # ELASTIC: resume on a smaller mesh
r2 = t2.run(9)
# reference: uninterrupted single-device run
arts = make_train_step(cfg, opt=AdamWConfig(lr=1e-3), impl="xla",
                       xla_chunk=32, donate=False)
p, o, _ = arts.init_fn(jax.random.PRNGKey(0))
for s in range(9):
    batch = {k: jnp.asarray(v) for k, v in make_batch(dc, s).items()}
    p, o, m = arts.step_fn(p, o, batch, jnp.int32(s))
errs = [float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(jax.tree.leaves(r2["params"]), jax.tree.leaves(p))]
print("elastic resume max err", max(errs))
assert max(errs) < 5e-5
print("PASS")
""")
    assert "PASS" in out


def test_int8_error_feedback_allreduce():
    """Compressed DP all-reduce ≈ exact mean; error feedback kills the bias
    across steps (mean of repeated reductions converges)."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed import shard_map
from repro.distributed.compression import quantize_psum, init_error_buffers

mesh = make_mesh((8,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 64)) * 0.01

def step(g_sharded, err):
    return quantize_psum(g_sharded, "data", err)

f = jax.jit(shard_map(step, mesh=mesh,
                      in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data"))))
exact = jnp.mean(g, axis=0)
err = jnp.zeros_like(g)
acc = jnp.zeros_like(exact)
n_steps = 20
for i in range(n_steps):
    mean_g, err = f(g, err)
    acc = acc + mean_g[0]
one_step_err = float(jnp.abs(mean_g[0] - exact).max())
avg_err = float(jnp.abs(acc / n_steps - exact).max())
print("one-step err", one_step_err, "avg err", avg_err)
assert one_step_err < 5e-4           # int8 quantisation noise
assert avg_err < one_step_err        # error feedback reduces bias over time
print("PASS")
""")
    assert "PASS" in out


def test_pallas_kernel_under_shard_map():
    """The fused kernel (interpret) runs under shard_map with heads sharded —
    the production pallas integration path."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed import shard_map
from repro.kernels.ops import mha, AttnConfig
from repro.kernels.ref import naive_mha

mesh = make_mesh((2, 4), ("data", "model"))
b, h, s, d = 4, 8, 128, 64
q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d))
k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, d))
v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d))
cfg = AttnConfig(causal=True, block_q=64, block_kv=64, interpret=True)

def local_attn(q, k, v):
    return mha(q, k, v, seed=0, config=cfg)

# the repro.distributed shard_map shim keeps replication checks off:
# pallas_call out_shapes carry no varying-mesh-axes info
f = jax.jit(shard_map(local_attn, mesh=mesh,
                      in_specs=(P("data", "model"),) * 3,
                      out_specs=P("data", "model")))
o = f(q, k, v)
o_ref = naive_mha(q, k, v, causal=True)
err = float(np.abs(np.asarray(o) - np.asarray(o_ref)).max())
print("shard_map kernel err", err)
assert err < 2e-5
print("PASS")
""")
    assert "PASS" in out


def test_dryrun_cell_small_mesh():
    """A scaled-down dry-run cell (sharded lower+compile+roofline) succeeds in
    CI — the full 512-device sweep runs via launch/dryrun.py."""
    out = run_sub(COMMON + """
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import lm
from repro.perf import collective_stats
cfg = dataclasses.replace(configs.get_config("granite_3_2b"), num_layers=4)
mesh = make_mesh((2, 4), ("data", "model"))
arts = make_train_step(cfg, mesh=mesh, impl="xla", donate=False)
params_sds, _ = lm.abstract_params(cfg, vocab_pad_to=4)
sds = lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh)
p_in = jax.tree.map(sds, params_sds, arts.shardings["params"])
from repro.optim import adamw_init
o_sds = jax.eval_shape(lambda p: adamw_init(p, AdamWConfig()), params_sds)
o_in = jax.tree.map(sds, o_sds, arts.shardings["opt"])
bsh = NamedSharding(mesh, P("data", None))
batch = {k: jax.ShapeDtypeStruct((8, 1024), jnp.int32, sharding=bsh)
         for k in ("tokens", "labels")}
st = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
compiled = arts.step_fn.lower(p_in, o_in, batch, st).compile()
stats = collective_stats(compiled.as_text(), default_group=8)
mem = compiled.memory_analysis()
print("collective kinds:", sorted(stats.count_by_kind))
assert stats.total_bytes > 0
assert mem.temp_size_in_bytes > 0
print("PASS")
""")
    assert "PASS" in out
