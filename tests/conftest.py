import os

# Smoke tests and benches must see ONE device; only launch/dryrun.py sets the
# 512-device XLA flag (before importing jax). Guard against env leakage.
os.environ.pop("XLA_FLAGS", None)

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def make_qkv(key, b, hq, hkv, sq, skv, d, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), dtype)
    do = jax.random.normal(ks[3], (b, hq, sq, d), dtype)
    return q, k, v, do


def max_err(a, b):
    return float(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max())
