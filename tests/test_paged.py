"""Paged-KV serving subsystem: kernel, cache bookkeeping, scheduler, engine.

The load-bearing contracts:
* paged flash-decode ≡ contiguous flash-decode on the same logical KV
  (bit-exact: the block-table gather only changes *where* pages live);
* both ≡ the naive oracle under ragged lengths, GQA and sliding windows;
* the allocator/block-table invariants (trash page reserved, pages returned
  on release, admission is all-or-nothing);
* continuous batching preserves per-request generations exactly: packed
  prefill + paged decode through the engine reproduces one-request-at-a-time
  contiguous serving token for token.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import max_err
from repro.core.attention import spark_paged_decode
from repro.kernels.ops import (decode, gather_pages, paged_decode,
                               paged_decode_reference)
from repro.serving import (BlockTables, PageAllocator, PagedCacheConfig,
                           Request, Scheduler, TRASH_PAGE)


def _mk_pool(key, b, hq, hkv, d, page_size, pages_per_row, extra_pages=3):
    """Random q + page pool + shuffled block tables for b rows."""
    num_pages = 1 + b * pages_per_row + extra_pages
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k_pages = jax.random.normal(ks[1], (hkv, num_pages, page_size, d))
    v_pages = jax.random.normal(ks[2], (hkv, num_pages, page_size, d))
    perm = np.random.RandomState(1).permutation(num_pages - 1) + 1
    bt = jnp.asarray(perm[:b * pages_per_row].reshape(b, pages_per_row),
                     jnp.int32)
    return q, k_pages, v_pages, bt


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------

CASES = [
    # hq, hkv, page_size, window
    (4, 4, 64, None),      # MHA
    (8, 2, 64, None),      # GQA: group packed into MXU rows
    (4, 2, 64, 100),       # sliding window masked in-kernel (no ring)
    (4, 1, 128, None),     # MQA, bigger pages
]


@pytest.mark.parametrize("hq,hkv,ps,window", CASES,
                         ids=[str(c) for c in CASES])
def test_paged_kernel_matches_oracle(rng_key, hq, hkv, ps, window):
    b, d, t = 3, 64, 4
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([t * ps, ps + 7, 3], jnp.int32)
    o = paged_decode(q, kp, vp, bt, kv_len, window=window, interpret=True)
    o_ref = paged_decode_reference(q, kp, vp, bt, kv_len, window=window)
    assert max_err(o, o_ref) < 2e-5


def test_paged_equals_contiguous_kernel(rng_key):
    """Same logical KV, scattered pages vs. contiguous layout: bit-exact."""
    b, hq, hkv, d, ps, t = 2, 8, 2, 64, 64, 4
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([t * ps, 2 * ps - 5], jnp.int32)
    kc, vc = gather_pages(kp, bt), gather_pages(vp, bt)
    o_paged = paged_decode(q, kp, vp, bt, kv_len, interpret=True)
    o_contig = decode(q, kc, vc, kv_len=kv_len, block_kv=ps, interpret=True)
    assert max_err(o_paged, o_contig) == 0.0


def test_paged_trash_entries_are_inert(rng_key):
    """Entries past a row's allocation point at the trash page; whatever it
    holds must not leak into the output (the kv_len mask gates it)."""
    b, hq, hkv, d, ps, t = 2, 4, 2, 64, 64, 4
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([ps + 3, 2 * ps], jnp.int32)
    bt_trashed = bt.at[:, 2:].set(TRASH_PAGE)     # rows only own 2 pages
    o1 = paged_decode(q, kp, vp, bt_trashed, kv_len, interpret=True)
    kp2 = kp.at[:, TRASH_PAGE].set(1e6)           # poison the trash page
    o2 = paged_decode(q, kp2, vp, bt_trashed, kv_len, interpret=True)
    assert max_err(o1, o2) == 0.0


def test_spark_paged_decode_xla_matches_kernel(rng_key):
    b, hq, hkv, d, ps, t = 2, 4, 2, 64, 64, 3
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([t * ps, 70], jnp.int32)
    o_k = spark_paged_decode(q, kp, vp, bt, kv_len, impl="pallas_interpret")
    o_x = spark_paged_decode(q, kp, vp, bt, kv_len, impl="xla")
    assert max_err(o_k, o_x) < 2e-5


# ---------------------------------------------------------------------------
# cache bookkeeping
# ---------------------------------------------------------------------------

def test_page_allocator_invariants():
    a = PageAllocator(num_pages=6)               # pages 1..5 usable
    assert a.num_free == 5
    got = a.alloc(3)
    assert got is not None and TRASH_PAGE not in got
    assert a.alloc(3) is None                    # all-or-nothing: 2 left
    assert a.num_free == 2                       # failed alloc had no effect
    a.free(got)
    assert a.num_free == 5
    assert sorted(a.alloc(5)) == [1, 2, 3, 4, 5]


def test_block_tables_admit_release_utilization():
    cfg = PagedCacheConfig(page_size=4, num_pages=9, max_batch=2,
                           max_pages_per_seq=4)
    bt = BlockTables(cfg)
    assert bt.admit(0, n_tokens=10)              # 3 pages
    assert bt.admit(1, n_tokens=14)              # 4 pages
    assert bt.allocator.num_free == 1
    bt.kv_len[0], bt.kv_len[1] = 10, 14
    u = bt.utilization()
    assert u["used_tokens"] == 24 and u["allocated_tokens"] == 28
    bt.release(0)
    assert bt.allocator.num_free == 4
    assert np.all(bt.tables[0] == TRASH_PAGE) and bt.kv_len[0] == 0
    with pytest.raises(ValueError):
        bt.admit(0, n_tokens=cfg.max_seq_len + 1)


def test_prefill_dest_math():
    cfg = PagedCacheConfig(page_size=4, num_pages=9, max_batch=2,
                           max_pages_per_seq=4)
    bt = BlockTables(cfg)
    assert bt.admit(0, 6) and bt.admit(1, 5)     # 2 pages each
    seg = np.array([0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, -1], np.int32)
    dest = bt.prefill_dest(seg, slots=[0, 1])
    t0, t1 = bt.tables[0], bt.tables[1]
    exp0 = [t0[0] * 4 + i for i in range(4)] + [t0[1] * 4, t0[1] * 4 + 1]
    exp1 = [t1[0] * 4 + i for i in range(4)] + [t1[1] * 4]
    assert list(dest[:6]) == exp0
    assert list(dest[6:11]) == exp1
    assert dest[11] < cfg.page_size              # padding → trash page slots


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_waves_and_fcfs():
    cfg = PagedCacheConfig(page_size=4, num_pages=5, max_batch=4,
                           max_pages_per_seq=4)
    sched = Scheduler(cfg)
    for rid in range(3):                         # each needs 2 pages; pool: 4
        sched.submit(Request(rid=rid, tokens=np.zeros(4, np.int32),
                             max_new_tokens=4))
    first = sched.admit()
    assert [s.request.rid for s in first] == [0, 1]   # FCFS, 2 fit
    assert sched.admit() == []                   # pool exhausted, order kept
    first[0].generated.extend([1] * 4)           # rid 0 finishes
    done = sched.evict_finished()
    assert [s.request.rid for s in done] == [0]
    second = sched.admit()                       # freed pages re-admit rid 2
    assert [s.request.rid for s in second] == [2]
    with pytest.raises(ValueError):              # can never fit → reject early
        sched.submit(Request(rid=9, tokens=np.zeros(14, np.int32),
                             max_new_tokens=4))


# ---------------------------------------------------------------------------
# end to end: packed prefill + paged decode ≡ contiguous serving
# ---------------------------------------------------------------------------

def _smoke_cfg():
    from repro import configs
    return dataclasses.replace(configs.smoke_config("qwen3_14b"),
                               dtype=jnp.float32, remat=False)


def test_engine_matches_contiguous_serving():
    from repro.models import lm
    from repro.runtime.steps import make_serve_steps
    from repro.serving import ServingEngine

    cfg = _smoke_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    # two prompt lengths only (bounds baseline recompiles); ragged budgets
    reqs = [(rs.randint(0, cfg.vocab_size, size=L).astype(np.int32), g)
            for L, g in [(12, 6), (7, 8), (12, 1), (7, 5)]]

    def contiguous_gen(prompt, max_new, max_len=24):
        arts = make_serve_steps(cfg, impl="xla", max_len=max_len, batch=1,
                                xla_chunk=16)
        caches = arts.cache_init_fn()
        logits, caches = arts.prefill_fn(params, jnp.asarray(prompt)[None],
                                         None, caches)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        out = [int(tok[0])]
        for i in range(max_new - 1):
            logits, caches = arts.decode_fn(params, tok, caches,
                                            jnp.int32(len(prompt) + i))
            tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
            out.append(int(tok[0]))
        return np.asarray(out, np.int32)

    expected = {i: contiguous_gen(p, g) for i, (p, g) in enumerate(reqs)}

    # pool sized so only ~2 sequences fit at once → real admission waves
    pcfg = PagedCacheConfig(page_size=8, num_pages=8, max_batch=2,
                            max_pages_per_seq=3)
    eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                        xla_chunk=16)
    out, stats = eng.run(reqs)
    assert stats["mean_utilization"] > 0.5       # pages track live tokens
    for rid, exp in expected.items():
        assert np.array_equal(out[rid], exp), \
            f"request {rid}: paged {out[rid]} != contiguous {exp}"
    # every page returned to the pool after the queue drained
    assert eng.scheduler.tables.allocator.num_free == pcfg.num_pages - 1


def test_packed_prefill_matches_per_prompt_prefill():
    """One packed prefill row fills two prompts' pages identically to two
    separate (unpacked) prefills — same last-token logits, same page bytes."""
    from repro.models import lm
    from repro.runtime.steps import make_serve_steps

    cfg = _smoke_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    rs = np.random.RandomState(3)
    lens = [9, 6]
    prompts = [rs.randint(0, cfg.vocab_size, size=L).astype(np.int32)
               for L in lens]
    pcfg = PagedCacheConfig(page_size=4, num_pages=9, max_batch=2,
                            max_pages_per_seq=3)
    arts = make_serve_steps(cfg, impl="xla", paged=pcfg, xla_chunk=16)

    def run_prefill(layouts):
        """layouts: list of (prompt, slot) packed into one row per call."""
        tables = BlockTables(pcfg)
        caches = arts.cache_init_fn()
        last = {}
        for group in layouts:
            S = 16
            tokens = np.zeros((1, S), np.int32)
            seg = np.full((1, S), -1, np.int32)
            pos = np.zeros((1, S), np.int32)
            off = 0
            for i, (prompt, slot) in enumerate(group):
                if slot not in tables._owned:
                    assert tables.admit(slot, len(prompt))
                n = len(prompt)
                tokens[0, off:off + n] = prompt
                seg[0, off:off + n] = i
                pos[0, off:off + n] = np.arange(n)
                off += n
            dest = tables.prefill_dest(seg[0], [s for _, s in group])
            logits, caches = arts.prefill_fn(
                params, jnp.asarray(tokens), jnp.asarray(seg),
                jnp.asarray(pos), jnp.asarray(dest[None]), caches)
            off = 0
            for i, (prompt, slot) in enumerate(group):
                off += len(prompt)
                last[slot] = np.asarray(logits[0, off - 1, :cfg.vocab_size])
        return last, caches

    packed, caches_p = run_prefill([[(prompts[0], 0), (prompts[1], 1)]])
    solo, caches_s = run_prefill([[(prompts[0], 0)], [(prompts[1], 1)]])
    for slot in (0, 1):
        assert max_err(packed[slot], solo[slot]) < 1e-5
    # the cache pages must match too (page allocation order is deterministic,
    # so the layouts agree page for page). Page 0 is excluded: it is the
    # trash page and absorbs each layout's different padding writes.
    for lp, ls in zip(jax.tree.leaves(caches_p), jax.tree.leaves(caches_s)):
        assert max_err(lp[..., 1:, :, :], ls[..., 1:, :, :]) < 1e-5
