"""Paged-KV serving subsystem: kernel, cache bookkeeping, scheduler, engine.

The load-bearing contracts:
* paged flash-decode ≡ contiguous flash-decode on the same logical KV
  (bit-exact: the block-table gather only changes *where* pages live);
* both ≡ the naive oracle under ragged lengths, GQA and sliding windows;
* the allocator/block-table invariants (trash page reserved, pages returned
  on release, admission is all-or-nothing);
* continuous batching preserves per-request generations exactly: packed
  prefill + paged decode through the engine reproduces one-request-at-a-time
  contiguous serving token for token;
* distributed paged serving (page-aligned pool shards, per-shard local
  attention + online-softmax partial merge) reproduces the single-device
  engine token for token — partial-state math in the fast tier, the real
  multi-device engine in a slow-tier subprocess with fake CPU devices;
* EOS finish: a sequence that emits its eos_id is evicted immediately (pages
  freed, decode steps saved), with the generation a prefix of the budget run;
* lazy admission (prompt-only reservation + one-page growth + youngest-row
  preemption/re-prefill) is token-identical to eager full-budget reservation
  under memory pressure that forces preemptions, at strictly higher pool
  utilization;
* sliding-window page reclamation frees only fully-out-of-window pages —
  poisoning every freed page (and the trash page) leaves generations
  bit-identical, so the kernels' window gate provably never reads them;
* recurrent-state slot lifecycle (StateCache) tracks page admission exactly:
  a slot's state row is bound on admit, released (and queued for poisoning)
  on release/preemption, and conserved under randomized churn —
  free + occupied == capacity at every step.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import max_err
from repro.core import online_softmax as osm
from repro.core.attention import spark_paged_decode, spark_paged_decode_partials
from repro.kernels.ops import (decode, gather_pages, paged_decode,
                               paged_decode_reference)
from repro.serving import (BlockTables, PageAllocator, PagedCacheConfig,
                           Request, Scheduler, StateCache, TRASH_PAGE)


def _mk_pool(key, b, hq, hkv, d, page_size, pages_per_row, extra_pages=3):
    """Random q + page pool + shuffled block tables for b rows."""
    num_pages = 1 + b * pages_per_row + extra_pages
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k_pages = jax.random.normal(ks[1], (hkv, num_pages, page_size, d))
    v_pages = jax.random.normal(ks[2], (hkv, num_pages, page_size, d))
    perm = np.random.RandomState(1).permutation(num_pages - 1) + 1
    bt = jnp.asarray(perm[:b * pages_per_row].reshape(b, pages_per_row),
                     jnp.int32)
    return q, k_pages, v_pages, bt


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------

CASES = [
    # hq, hkv, page_size, window
    (4, 4, 64, None),      # MHA
    (8, 2, 64, None),      # GQA: group packed into MXU rows
    (4, 2, 64, 100),       # sliding window masked in-kernel (no ring)
    (4, 1, 128, None),     # MQA, bigger pages
]


@pytest.mark.parametrize("hq,hkv,ps,window", CASES,
                         ids=[str(c) for c in CASES])
def test_paged_kernel_matches_oracle(rng_key, hq, hkv, ps, window):
    b, d, t = 3, 64, 4
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([t * ps, ps + 7, 3], jnp.int32)
    o = paged_decode(q, kp, vp, bt, kv_len, window=window, interpret=True)
    o_ref = paged_decode_reference(q, kp, vp, bt, kv_len, window=window)
    assert max_err(o, o_ref) < 2e-5


def test_paged_equals_contiguous_kernel(rng_key):
    """Same logical KV, scattered pages vs. contiguous layout: bit-exact."""
    b, hq, hkv, d, ps, t = 2, 8, 2, 64, 64, 4
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([t * ps, 2 * ps - 5], jnp.int32)
    kc, vc = gather_pages(kp, bt), gather_pages(vp, bt)
    o_paged = paged_decode(q, kp, vp, bt, kv_len, interpret=True)
    o_contig = decode(q, kc, vc, kv_len=kv_len, block_kv=ps, interpret=True)
    assert max_err(o_paged, o_contig) == 0.0


def test_paged_trash_entries_are_inert(rng_key):
    """Entries past a row's allocation point at the trash page; whatever it
    holds must not leak into the output (the kv_len mask gates it)."""
    b, hq, hkv, d, ps, t = 2, 4, 2, 64, 64, 4
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([ps + 3, 2 * ps], jnp.int32)
    bt_trashed = bt.at[:, 2:].set(TRASH_PAGE)     # rows only own 2 pages
    o1 = paged_decode(q, kp, vp, bt_trashed, kv_len, interpret=True)
    kp2 = kp.at[:, TRASH_PAGE].set(1e6)           # poison the trash page
    o2 = paged_decode(q, kp2, vp, bt_trashed, kv_len, interpret=True)
    assert max_err(o1, o2) == 0.0


def test_spark_paged_decode_xla_matches_kernel(rng_key):
    b, hq, hkv, d, ps, t = 2, 4, 2, 64, 64, 3
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([t * ps, 70], jnp.int32)
    o_k = spark_paged_decode(q, kp, vp, bt, kv_len, impl="pallas_interpret")
    o_x = spark_paged_decode(q, kp, vp, bt, kv_len, impl="xla")
    assert max_err(o_k, o_x) < 2e-5


# ---------------------------------------------------------------------------
# distributed building block: per-shard partials + online-softmax merge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("window", [None, 20], ids=["full", "win20"])
def test_paged_partials_merge_equals_full(rng_key, impl, window):
    """Split the pool into two page-aligned 'shards' by hand: local partial
    attention per shard (non-local table entries remapped to the shard's
    trash page and masked via block_valid) merged with online_softmax.merge
    must reproduce the single-pool decode — the distributed serving math,
    exercised without any devices."""
    b, hq, hkv, d, ps, t = 3, 8, 2, 64, 16, 4
    num_pages, n_shards = 8, 2
    n_local = num_pages // n_shards
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kp = jax.random.normal(ks[1], (hkv, num_pages, ps, d))
    vp = jax.random.normal(ks[2], (hkv, num_pages, ps, d))
    # pages 0 and 4 are the per-shard trash pages; tables use the rest
    usable = np.array([1, 2, 3, 5, 6, 7])
    rs = np.random.RandomState(1)
    bt = jnp.asarray(np.stack([rs.permutation(usable)[:t] for _ in range(b)]
                              ).astype(np.int32))
    kv_len = jnp.array([t * ps, ps + 5, 3], jnp.int32)

    full = spark_paged_decode(q, kp, vp, bt, kv_len, impl=impl, window=window)
    states = []
    for s in range(n_shards):
        owner = bt // n_local
        valid = (owner == s).astype(jnp.int32)
        bt_local = jnp.where(owner == s, bt % n_local, 0)
        acc, m, l = spark_paged_decode_partials(
            q, kp[:, s * n_local:(s + 1) * n_local],
            vp[:, s * n_local:(s + 1) * n_local], bt_local, kv_len,
            block_valid=valid, impl=impl, window=window)
        states.append(osm.SoftmaxState(m=m, l=l, acc=acc))
    o, _ = osm.finalize(osm.merge(states[0], states[1]), out_dtype=q.dtype)
    assert max_err(o, full) < 2e-5


def test_paged_partials_trash_poison_inert(rng_key):
    """Poisoning a shard's trash page must not leak through block_valid."""
    b, hq, hkv, d, ps, t = 2, 4, 2, 32, 16, 2
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kp = jax.random.normal(ks[1], (hkv, 4, ps, d))
    vp = jax.random.normal(ks[2], (hkv, 4, ps, d))
    bt = jnp.array([[1, 2], [3, 1]], jnp.int32)
    kv_len = jnp.array([2 * ps, ps + 3], jnp.int32)
    valid = jnp.array([[1, 0], [0, 1]], jnp.int32)   # pretend half is foreign
    bt_masked = jnp.where(valid == 1, bt, 0)
    ref = spark_paged_decode_partials(q, kp, vp, bt_masked, kv_len,
                                      block_valid=valid, impl="xla")
    kp2 = kp.at[:, 0].set(1e6)                       # poison the trash page
    out = spark_paged_decode_partials(q, kp2, vp, bt_masked, kv_len,
                                      block_valid=valid, impl="xla")
    for a, b_ in zip(ref, out):
        assert max_err(a, b_) == 0.0


# ---------------------------------------------------------------------------
# cache bookkeeping
# ---------------------------------------------------------------------------

def test_page_allocator_invariants():
    a = PageAllocator(num_pages=6)               # pages 1..5 usable
    assert a.num_free == 5
    got = a.alloc(3)
    assert got is not None and TRASH_PAGE not in got
    assert a.alloc(3) is None                    # all-or-nothing: 2 left
    assert a.num_free == 2                       # failed alloc had no effect
    a.free(got)
    assert a.num_free == 5
    assert sorted(a.alloc(5)) == [1, 2, 3, 4, 5]


def test_page_allocator_per_shard_trash_pages():
    """Distributed pool: page 0 of every shard (global s·P) is reserved."""
    a = PageAllocator(num_pages=8, num_shards=2)     # trash: 0 and 4
    assert a.num_free == 6
    got = a.alloc(6)
    assert sorted(got) == [1, 2, 3, 5, 6, 7]
    with pytest.raises(ValueError):
        a.free([4])                                  # shard-1 trash page
    a.free(got)
    assert a.num_free == 6
    # config level: validation + derived geometry
    cfg = PagedCacheConfig(page_size=4, num_pages=8, max_batch=2,
                           max_pages_per_seq=4, num_shards=2)
    assert cfg.trash_pages == frozenset({0, 4}) and cfg.usable_pages == 6
    with pytest.raises(ValueError):                  # pages straddle shards
        PagedCacheConfig(page_size=4, num_pages=9, max_batch=2,
                         max_pages_per_seq=4, num_shards=2)


def test_block_tables_admit_release_utilization():
    cfg = PagedCacheConfig(page_size=4, num_pages=9, max_batch=2,
                           max_pages_per_seq=4)
    bt = BlockTables(cfg)
    assert bt.admit(0, n_tokens=10)              # 3 pages
    assert bt.admit(1, n_tokens=14)              # 4 pages
    assert bt.allocator.num_free == 1
    bt.kv_len[0], bt.kv_len[1] = 10, 14
    u = bt.utilization()
    assert u["used_tokens"] == 24 and u["allocated_tokens"] == 28
    bt.release(0)
    assert bt.allocator.num_free == 4
    assert np.all(bt.tables[0] == TRASH_PAGE) and bt.kv_len[0] == 0
    with pytest.raises(ValueError):
        bt.admit(0, n_tokens=cfg.max_seq_len + 1)


def test_block_tables_lazy_growth():
    """grow() allocates exactly the next write block, idempotently, and
    reports pool exhaustion without side effects."""
    cfg = PagedCacheConfig(page_size=4, num_pages=5, max_batch=2,
                           max_pages_per_seq=4)          # 4 usable pages
    bt = BlockTables(cfg)
    assert bt.admit(0, n_tokens=6)               # prompt-only: 2 pages
    bt.kv_len[0] = 6
    assert bt.append_dest_ok(0)                  # position 6 is in block 1
    assert bt.grow(0) and bt.pages_grown == 0    # idempotent: no allocation
    bt.kv_len[0] = 8                             # next write crosses a page
    assert not bt.append_dest_ok(0)
    assert bt.grow(0) and bt.pages_grown == 1
    assert bt.append_dest_ok(0)
    assert bt.tables[0, 2] != TRASH_PAGE
    assert bt.admit(1, n_tokens=4)               # 1 page → pool dry
    bt.kv_len[1] = 4
    free_before = bt.allocator.num_free
    assert not bt.grow(1)                        # dry: False, no side effect
    assert bt.allocator.num_free == free_before == 0
    bt.kv_len[0] = 11                            # last position of block 2
    assert bt.append_dest_ok(0)
    bt.kv_len[0] = 16                            # beyond the 4-block table
    with pytest.raises(ValueError):
        bt.grow(0)


def test_block_tables_window_reclaim():
    """reclaim_out_of_window frees exactly the blocks whose every position
    the decode kernels' window gate masks out — never an in-window page."""
    cfg = PagedCacheConfig(page_size=4, num_pages=10, max_batch=1,
                           max_pages_per_seq=6)
    bt = BlockTables(cfg)
    assert bt.admit(0, n_tokens=20)              # blocks 0..4
    bt.kv_len[0] = 20
    window = 6
    # next decode: q_pos = 20, keys allowed at positions > 14 → blocks 0..2
    # (last positions 3, 7, 11) are dead; block 3 (last position 15) lives
    freed = bt.reclaim_out_of_window(0, window)
    assert len(freed) == 3 and bt.pages_reclaimed == 3
    assert all(bt.tables[0, blk] == TRASH_PAGE for blk in range(3))
    assert all(bt.tables[0, blk] != TRASH_PAGE for blk in (3, 4))
    assert sorted(bt._owned[0]) == [3, 4]
    assert bt.reclaim_out_of_window(0, window) == []   # idempotent at this L
    u = bt.utilization()
    assert u["allocated_tokens"] == 8.0          # 2 owned pages
    assert u["used_tokens"] == 8.0               # tokens resident in them
    bt.kv_len[0] = 22                            # window slides with kv_len
    assert len(bt.reclaim_out_of_window(0, window)) == 1   # block 3 dies
    bt.release(0)
    assert bt.allocator.num_free == cfg.usable_pages
    # windowed admission skips the blocks reclaim would free immediately: a
    # resumed 20-token prompt reserves only its in-window tail (same horizon)
    sched = Scheduler(cfg, lazy=True, window=window)
    sched.submit(Request(rid=0, tokens=np.zeros(20, np.int32),
                         max_new_tokens=4))
    (seq,) = sched.admit()
    assert sorted(sched.tables._owned[seq.slot]) == [3, 4]
    assert all(sched.tables.tables[seq.slot, blk] == TRASH_PAGE
               for blk in range(3))


def test_scheduler_lazy_preempts_youngest_and_resumes():
    """Pool runs dry mid-growth → the youngest row is preempted: pages
    freed, request re-queued at the FRONT with generated tokens folded into
    the prompt and the budget shrunk; admission later resumes it."""
    cfg = PagedCacheConfig(page_size=4, num_pages=6, max_batch=2,
                           max_pages_per_seq=4)          # 5 usable pages
    sched = Scheduler(cfg, lazy=True)
    sched.submit(Request(rid=0, tokens=np.arange(8, dtype=np.int32),
                         max_new_tokens=8))
    sched.submit(Request(rid=1, tokens=np.arange(4, dtype=np.int32),
                         max_new_tokens=8))
    s0, s1 = sched.admit()                       # lazy: 2 + 1 pages
    assert sched.tables.allocator.num_free == 2
    s0.generated, s1.generated = [11], [21]
    sched.tables.kv_len[s0.slot], sched.tables.kv_len[s1.slot] = 8, 4
    assert sched.ensure_growth() == []           # 2 free pages cover both
    assert sched.tables.allocator.num_free == 0
    s0.generated += [12, 13, 14, 15]
    s1.generated += [22, 23, 24]
    sched.tables.kv_len[s0.slot], sched.tables.kv_len[s1.slot] = 12, 8
    preempted = sched.ensure_growth()            # dry → youngest (rid 1) out
    assert preempted == [1] and sched.preemptions == 1
    assert list(sched.active) == [s0.slot]
    assert sched.tables.append_dest_ok(s0.slot)  # the older row kept growing
    resumed = sched.waiting[0]                   # re-queued at the front
    assert resumed.rid == 1
    assert list(resumed.tokens) == list(np.arange(4)) + [21, 22, 23, 24]
    assert resumed.max_new_tokens == 4           # 8 - 4 already generated
    assert resumed.generated_prefix == [21, 22, 23, 24]
    assert resumed.budget_tokens == 12           # invariant under preemption
    # the survivor finishes → its pages cover the resumed prefix
    s0.generated += [16, 17, 18]                 # hits the budget of 8
    sched.evict_finished()
    (s1b,) = sched.admit()
    assert s1b.request.rid == 1 and s1b.request.generated_prefix == [21, 22,
                                                                     23, 24]


def test_prefill_dest_math():
    cfg = PagedCacheConfig(page_size=4, num_pages=9, max_batch=2,
                           max_pages_per_seq=4)
    bt = BlockTables(cfg)
    assert bt.admit(0, 6) and bt.admit(1, 5)     # 2 pages each
    seg = np.array([0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, -1], np.int32)
    dest = bt.prefill_dest(seg, slots=[0, 1])
    t0, t1 = bt.tables[0], bt.tables[1]
    exp0 = [t0[0] * 4 + i for i in range(4)] + [t0[1] * 4, t0[1] * 4 + 1]
    exp1 = [t1[0] * 4 + i for i in range(4)] + [t1[1] * 4]
    assert list(dest[:6]) == exp0
    assert list(dest[6:11]) == exp1
    assert dest[11] < cfg.page_size              # padding → trash page slots


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_waves_and_fcfs():
    cfg = PagedCacheConfig(page_size=4, num_pages=5, max_batch=4,
                           max_pages_per_seq=4)
    sched = Scheduler(cfg)
    for rid in range(3):                         # each needs 2 pages; pool: 4
        sched.submit(Request(rid=rid, tokens=np.zeros(4, np.int32),
                             max_new_tokens=4))
    first = sched.admit()
    assert [s.request.rid for s in first] == [0, 1]   # FCFS, 2 fit
    assert sched.admit() == []                   # pool exhausted, order kept
    first[0].generated.extend([1] * 4)           # rid 0 finishes
    done = sched.evict_finished()
    assert [s.request.rid for s in done] == [0]
    second = sched.admit()                       # freed pages re-admit rid 2
    assert [s.request.rid for s in second] == [2]
    with pytest.raises(ValueError):              # can never fit → reject early
        sched.submit(Request(rid=9, tokens=np.zeros(14, np.int32),
                             max_new_tokens=4))


def test_eos_finishes_sequence_early():
    """ActiveSeq.done fires on the EOS token, not just the budget."""
    from repro.serving import ActiveSeq
    req = Request(rid=0, tokens=np.zeros(4, np.int32), max_new_tokens=8,
                  eos_id=7)
    seq = ActiveSeq(request=req, slot=0)
    seq.generated.extend([3, 5])
    assert not seq.done
    seq.generated.append(7)                      # EOS
    assert seq.done
    # without an eos_id the same tokens run to the budget
    req2 = Request(rid=1, tokens=np.zeros(4, np.int32), max_new_tokens=8)
    seq2 = ActiveSeq(request=req2, slot=1)
    seq2.generated.extend([3, 5, 7])
    assert not seq2.done
    seq2.generated.extend([7] * 5)
    assert seq2.done                             # budget


# ---------------------------------------------------------------------------
# end to end: packed prefill + paged decode ≡ contiguous serving
# ---------------------------------------------------------------------------

def _smoke_cfg():
    from repro import configs
    return dataclasses.replace(configs.smoke_config("qwen3_14b"),
                               dtype=jnp.float32, remat=False)


def test_engine_matches_contiguous_serving():
    from repro.models import lm
    from repro.runtime.steps import make_serve_steps
    from repro.serving import ServingEngine

    cfg = _smoke_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    # two prompt lengths only (bounds baseline recompiles); ragged budgets
    reqs = [(rs.randint(0, cfg.vocab_size, size=L).astype(np.int32), g)
            for L, g in [(12, 6), (7, 8), (12, 1), (7, 5)]]

    def contiguous_gen(prompt, max_new, max_len=24):
        arts = make_serve_steps(cfg, impl="xla", max_len=max_len, batch=1,
                                xla_chunk=16)
        caches = arts.cache_init_fn()
        logits, caches = arts.prefill_fn(params, jnp.asarray(prompt)[None],
                                         None, caches)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        out = [int(tok[0])]
        for i in range(max_new - 1):
            logits, caches = arts.decode_fn(params, tok, caches,
                                            jnp.int32(len(prompt) + i))
            tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
            out.append(int(tok[0]))
        return np.asarray(out, np.int32)

    expected = {i: contiguous_gen(p, g) for i, (p, g) in enumerate(reqs)}

    # pool sized so only ~2 sequences fit at once → real admission waves
    pcfg = PagedCacheConfig(page_size=8, num_pages=8, max_batch=2,
                            max_pages_per_seq=3)
    eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                        xla_chunk=16)
    out, stats = eng.run(reqs)
    assert stats["mean_utilization"] > 0.5       # pages track live tokens
    for rid, exp in expected.items():
        assert np.array_equal(out[rid], exp), \
            f"request {rid}: paged {out[rid]} != contiguous {exp}"
    # every page returned to the pool after the queue drained
    assert eng.scheduler.tables.allocator.num_free == pcfg.num_pages - 1


def test_packed_prefill_matches_per_prompt_prefill():
    """One packed prefill row fills two prompts' pages identically to two
    separate (unpacked) prefills — same last-token logits, same page bytes."""
    from repro.models import lm
    from repro.runtime.steps import make_serve_steps

    cfg = _smoke_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    rs = np.random.RandomState(3)
    lens = [9, 6]
    prompts = [rs.randint(0, cfg.vocab_size, size=L).astype(np.int32)
               for L in lens]
    pcfg = PagedCacheConfig(page_size=4, num_pages=9, max_batch=2,
                            max_pages_per_seq=3)
    arts = make_serve_steps(cfg, impl="xla", paged=pcfg, xla_chunk=16)

    def run_prefill(layouts):
        """layouts: list of (prompt, slot) packed into one row per call."""
        tables = BlockTables(pcfg)
        caches = arts.cache_init_fn()
        last = {}
        for group in layouts:
            S = 16
            tokens = np.zeros((1, S), np.int32)
            seg = np.full((1, S), -1, np.int32)
            pos = np.zeros((1, S), np.int32)
            slots = np.full((1, S), -1, np.int32)
            off = 0
            for i, (prompt, slot) in enumerate(group):
                if slot not in tables._owned:
                    assert tables.admit(slot, len(prompt))
                n = len(prompt)
                tokens[0, off:off + n] = prompt
                seg[0, off:off + n] = i
                pos[0, off:off + n] = np.arange(n)
                slots[0, off:off + n] = slot
                off += n
            dest = tables.prefill_dest(seg[0], [s for _, s in group])
            logits, caches = arts.prefill_fn(
                params, jnp.asarray(tokens), jnp.asarray(seg),
                jnp.asarray(pos), jnp.asarray(dest[None]),
                jnp.asarray(slots), caches)
            off = 0
            for i, (prompt, slot) in enumerate(group):
                off += len(prompt)
                last[slot] = np.asarray(logits[0, off - 1, :cfg.vocab_size])
        return last, caches

    packed, caches_p = run_prefill([[(prompts[0], 0), (prompts[1], 1)]])
    solo, caches_s = run_prefill([[(prompts[0], 0)], [(prompts[1], 1)]])
    for slot in (0, 1):
        assert max_err(packed[slot], solo[slot]) < 1e-5
    # the cache pages must match too (page allocation order is deterministic,
    # so the layouts agree page for page). Page 0 is excluded: it is the
    # trash page and absorbs each layout's different padding writes.
    for lp, ls in zip(jax.tree.leaves(caches_p), jax.tree.leaves(caches_s)):
        assert max_err(lp[..., 1:, :, :], ls[..., 1:, :, :]) < 1e-5


def test_lazy_engine_matches_eager_under_preemption():
    """The acceptance contract of scheduler v2: with a pool tight enough to
    force at least one preemption, the lazy engine (prompt-only admission +
    growth + preempt/re-prefill) generates exactly the eager full-budget
    engine's tokens, at strictly higher reserved-vs-live page utilization."""
    from repro.models import lm
    from repro.serving import ServingEngine

    cfg = _smoke_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    reqs = [(rs.randint(0, cfg.vocab_size, size=9).astype(np.int32), 6),
            (rs.randint(0, cfg.vocab_size, size=5).astype(np.int32), 8)]
    # 6 usable pages: eager serves the two 4-page-budget requests serially;
    # lazy admits both at once (3 + 2 prompt pages) and runs dry growing
    pcfg = PagedCacheConfig(page_size=4, num_pages=7, max_batch=2,
                            max_pages_per_seq=4)

    def run(lazy):
        eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=16,
                            xla_chunk=16, lazy=lazy)
        out, stats = eng.run(list(reqs))
        # every page back in the pool once the queue drains
        assert eng.scheduler.tables.allocator.num_free == pcfg.usable_pages
        return out, stats

    out_e, st_e = run(lazy=False)
    out_l, st_l = run(lazy=True)
    assert st_e["preemptions"] == 0 and st_e["pages_grown"] == 0
    assert st_l["preemptions"] >= 1          # the pressure actually bit
    assert set(out_e) == set(out_l)
    for rid in out_e:
        assert np.array_equal(out_l[rid], out_e[rid]), \
            f"request {rid}: lazy {out_l[rid]} != eager {out_e[rid]}"
    assert st_l["mean_utilization"] > st_e["mean_utilization"]


def test_window_reclamation_poisoned_pages_inert():
    """Sliding-window serving frees pages that slid fully out of the window.
    Poisoning every freed page (and the trash page their table entries now
    alias) with 1e6 must leave the generation bit-identical to a run that
    never reclaims — i.e. reclamation never frees an in-window page and the
    kernels' window gate never reads a reclaimed one."""
    from repro.models import lm
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(_smoke_cfg(), attn_window=10)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    rs = np.random.RandomState(1)
    reqs = [(rs.randint(0, cfg.vocab_size, size=8).astype(np.int32), 12),
            (rs.randint(0, cfg.vocab_size, size=11).astype(np.int32), 9)]
    # 5 usable pages vs a ~3-page window footprint per row: tight enough
    # that lazy growth preempts, so the preempt/re-prefill path runs
    # *combined* with reclamation (a resumed long-tail prompt re-admits
    # with only its in-window blocks reserved)
    pcfg = PagedCacheConfig(page_size=4, num_pages=6, max_batch=2,
                            max_pages_per_seq=6)

    def run(**kw):
        eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                            xla_chunk=16, lazy=True, **kw)
        out, stats = eng.run(list(reqs))
        assert eng.scheduler.tables.allocator.num_free == pcfg.usable_pages
        return out, stats

    out_ref, st_ref = run(reclaim=False)
    out_rec, st_rec = run(poison_reclaimed=True)
    assert st_ref["pages_reclaimed"] == 0
    assert st_rec["pages_reclaimed"] > 0     # long tails actually reclaimed
    assert st_rec["preemptions"] >= 1        # ...while preemption also bites
    for rid in out_ref:
        assert np.array_equal(out_rec[rid], out_ref[rid]), \
            f"request {rid}: reclaimed {out_rec[rid]} != pinned {out_ref[rid]}"
    # reclamation holds O(window) pages per long row instead of O(seq):
    # the pool footprint must shrink (the utilization *fraction* may not —
    # a window straddling two partially-dead pages is sparser per page)
    assert st_rec["mean_pool_fraction"] < st_ref["mean_pool_fraction"]


def test_engine_eos_early_finish():
    """EOS eviction: generation is a prefix of the budget run, the decode
    loop stops spending steps on the finished sequence, and its pages return
    to the pool."""
    from repro.models import lm
    from repro.serving import ServingEngine

    cfg = _smoke_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    prompt = rs.randint(0, cfg.vocab_size, size=12).astype(np.int32)
    pcfg = PagedCacheConfig(page_size=8, num_pages=8, max_batch=2,
                            max_pages_per_seq=3)

    def run(eos_id):
        eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                            xla_chunk=16)
        eng.submit(prompt, 8, eos_id=eos_id)
        out, stats = eng.run()
        assert eng.scheduler.tables.allocator.num_free == pcfg.usable_pages
        return out[0], stats

    ref, ref_stats = run(None)                       # runs to the budget
    assert len(ref) == 8
    eos = int(ref[2])                                # make step 3 the EOS
    got, got_stats = run(eos)
    assert list(got) == list(ref[:3])                # prefix, ends at EOS
    assert got_stats["decode_steps"] < ref_stats["decode_steps"]


# ---------------------------------------------------------------------------
# distributed: sharded engine ≡ single-device engine (fake CPU devices)
# ---------------------------------------------------------------------------

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_sharded_engine_matches_single_device():
    """Paged serving on a 2-way ("model",) mesh — page pool sharded
    page-aligned, decode via per-shard partials + online-softmax merge —
    reproduces the single-device engine token for token, in both admission
    modes. The lazy run uses a pool tight enough to force a preemption, so
    growth/preempt/re-prefill exercise the sharded decode path too (block
    tables keep global ids: every shard sees the same post-growth tables
    each step). Subprocess: the fake-device XLA flag must be set before jax
    initialises."""
    code = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.serving import PagedCacheConfig, ServingEngine

cfg = dataclasses.replace(configs.smoke_config("qwen3_14b"),
                          dtype=jnp.float32, remat=False)
params, _ = lm.init_params(cfg, jax.random.PRNGKey(0), vocab_pad_to=2)
rs = np.random.RandomState(0)
reqs = [(rs.randint(0, cfg.vocab_size, size=L).astype(np.int32), g)
        for L, g in [(12, 6), (7, 8), (12, 1), (7, 5)]]

pcfg = PagedCacheConfig(page_size=8, num_pages=8, max_batch=2,
                        max_pages_per_seq=3)
eng1 = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                     xla_chunk=16)
out1, _ = eng1.run(list(reqs))

mesh = make_mesh((2,), ("model",))
pcfg2 = dataclasses.replace(pcfg, num_shards=2)
eng2 = ServingEngine(cfg, pcfg2, params, impl="xla", prefill_len=24,
                     xla_chunk=16, mesh=mesh)
out2, stats2 = eng2.run(list(reqs))

assert set(out1) == set(out2)
for rid in out1:
    assert np.array_equal(out1[rid], out2[rid]), \\
        f"request {rid}: sharded {out2[rid]} != single-device {out1[rid]}"
assert eng2.scheduler.tables.allocator.num_free == pcfg2.usable_pages

# lazy + sharded: 6-page pool → 4 usable across 2 shards; growth runs the
# pool dry and preempts, all against the sharded decode/prefill steps
pcfg3 = PagedCacheConfig(page_size=8, num_pages=6, max_batch=2,
                         max_pages_per_seq=3, num_shards=2)
eng3 = ServingEngine(cfg, pcfg3, params, impl="xla", prefill_len=24,
                     xla_chunk=16, mesh=mesh, lazy=True)
out3, stats3 = eng3.run(list(reqs))
assert stats3["preemptions"] >= 1, stats3
assert stats3["pages_grown"] >= 1, stats3
for rid in out1:
    assert np.array_equal(out1[rid], out3[rid]), \\
        f"request {rid}: sharded-lazy {out3[rid]} != eager {out1[rid]}"
assert eng3.scheduler.tables.allocator.num_free == pcfg3.usable_pages
print("PASS")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=env, capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    assert "PASS" in out.stdout


# ---------------------------------------------------------------------------
# recurrent-state slot cache (StateCache)
# ---------------------------------------------------------------------------

def test_state_cache_tracks_preemption_lifecycle():
    """Mirror of test_scheduler_lazy_preempts_youngest_and_resumes at the
    state layer: admission binds a slot's recurrent-state row, preemption
    releases it (queued for poisoning), and the resumed request re-admits
    a freshly-poisoned row — occupancy tracks the scheduler exactly."""
    cfg = PagedCacheConfig(page_size=4, num_pages=6, max_batch=2,
                           max_pages_per_seq=4)
    sched = Scheduler(cfg, lazy=True)
    state = sched.tables.state
    assert state.num_free == 2 and state.num_occupied == 0
    sched.submit(Request(rid=0, tokens=np.arange(8, dtype=np.int32),
                         max_new_tokens=8))
    sched.submit(Request(rid=1, tokens=np.arange(4, dtype=np.int32),
                         max_new_tokens=8))
    s0, s1 = sched.admit()
    assert state.num_occupied == 2 and state.num_free == 0
    assert state.occupied(s0.slot) and state.occupied(s1.slot)
    assert state.drain_released() == []          # nothing released yet
    s0.generated, s1.generated = [11], [21]
    sched.tables.kv_len[s0.slot], sched.tables.kv_len[s1.slot] = 8, 4
    sched.ensure_growth()
    s0.generated += [12, 13, 14, 15]
    s1.generated += [22, 23, 24]
    sched.tables.kv_len[s0.slot], sched.tables.kv_len[s1.slot] = 12, 8
    preempted_slot = s1.slot
    assert sched.ensure_growth() == [1]          # youngest (rid 1) preempted
    # the preempted row's state died with its pages
    assert not state.occupied(preempted_slot)
    assert state.num_occupied == 1 and state.num_free == 1
    assert state.drain_released() == [preempted_slot]
    assert state.drain_released() == []          # drain-once semantics
    # survivor finishes → its row is released too; the resumed request then
    # re-admits into a clean row
    s0.generated += [16, 17, 18]
    sched.evict_finished()
    assert state.num_occupied == 0
    assert state.drain_released() == [s0.slot]
    (s1b,) = sched.admit()
    assert state.occupied(s1b.slot) and state.num_occupied == 1
    assert state.admits == 3 and state.releases == 2


def test_state_cache_guards():
    c = StateCache(2)
    c.admit(0)
    with pytest.raises(ValueError):
        c.admit(0)                               # double admit
    with pytest.raises(ValueError):
        c.admit(2)                               # out of range
    with pytest.raises(ValueError):
        c.release(1)                             # never admitted
    c.release(0)
    with pytest.raises(ValueError):
        c.release(0)                             # double release


def test_state_cache_randomized_conservation():
    """Random admit/release churn via the scheduler keeps state slots
    conserved (free + occupied == capacity, the sets disjoint) and in
    lock-step with page-table slot ownership; every released slot shows
    up in the poison queue exactly once."""
    cfg = PagedCacheConfig(page_size=4, num_pages=12, max_batch=3,
                           max_pages_per_seq=5)
    rs = np.random.RandomState(7)
    sched = Scheduler(cfg, lazy=True)
    state = sched.tables.state
    next_rid = 0
    drained = []

    def check():
        assert state.num_free + state.num_occupied == cfg.max_batch
        occ = {s for s in range(cfg.max_batch) if state.occupied(s)}
        assert len(occ) == state.num_occupied
        assert occ == set(sched.tables._owned)   # lock-step with the pages

    for step in range(300):
        op = rs.randint(5)
        if op == 0 and len(sched.waiting) < 4:
            sched.submit(Request(
                rid=next_rid,
                tokens=rs.randint(0, 5, size=int(rs.randint(2, 10))
                                  ).astype(np.int32),
                max_new_tokens=int(rs.randint(1, 6))))
            next_rid += 1
        elif op == 1:
            for seq in sched.admit():
                seq.prefilled = seq.request.prompt_len
                sched.tables.kv_len[seq.slot] = seq.request.prompt_len
                seq.generated.append(int(rs.randint(5)))
        elif op == 2 and sched.active:
            sched.ensure_growth()
            for seq in list(sched.active.values()):
                if not seq.done and sched.tables.append_dest_ok(seq.slot):
                    sched.tables.kv_len[seq.slot] += 1
                    seq.generated.append(int(rs.randint(5)))
        elif op == 3:
            sched.evict_finished()
        elif op == 4:
            drained.extend(state.drain_released())
        check()
    sched.evict_finished()
    drained.extend(state.drain_released())
    # every release was queued for poisoning exactly once
    assert len(drained) == state.releases
    assert state.admits - state.releases == state.num_occupied
