"""Cross-config serving conformance matrix: every config in
``src/repro/configs`` is served through the paged engine and checked
token-identical against the contiguous single-sequence oracle.

The zoo pins the serving contract per architecture *family*:

  attention   (llava_next_34b, granite_3_2b, qwen3_14b, deepseek_67b,
               deepseek_coder_33b)      — paged KV pages only
  attention+moe (dbrx_132b, deepseek_moe_16b) — stateless expert routing
               rides the existing paged path unchanged
  hybrid rec  (recurrentgemma_2b)       — rgLRU hidden + conv state rows
               in the paged StateCache
  ssm         (falcon_mamba_7b)         — mamba h/conv state rows
  encoder     (hubert_xlarge)           — no decode step: the engine
               must refuse it at construction

Scenarios: eager, lazy + forced preemption (tiny pool, reclaimed state
rows poisoned), chunked prefill (exercises the recurrent continuation /
conv-tail carry), prefix sharing where applicable (attention-only — the
recurrent archs must refuse), speculative decoding gating, and
num_splits > 1 decode.  Heavy configs run the eager check under the
slow marker; the fast tier keeps one representative per family.

Numerics: token identity via argmax, the repo standard — associative-
scan-vs-step and padded-width grouping differ only in ulps.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.serving import PagedCacheConfig, ServingEngine

# every causal config, grouped by cost: the fast tier keeps one
# representative per architecture family, the rest run under -m slow
FAST_ARCHS = ["granite_3_2b", "deepseek_moe_16b", "recurrentgemma_2b",
              "falcon_mamba_7b"]
SLOW_ARCHS = ["llava_next_34b", "qwen3_14b", "deepseek_67b",
              "deepseek_coder_33b", "dbrx_132b"]
CAUSAL_ARCHS = FAST_ARCHS + SLOW_ARCHS
ENCODER_ARCHS = ["hubert_xlarge"]
RECURRENT_ARCHS = ["recurrentgemma_2b", "falcon_mamba_7b"]

_zoo_param = pytest.mark.parametrize(
    "arch", FAST_ARCHS + [pytest.param(a, marks=pytest.mark.slow)
                          for a in SLOW_ARCHS])


def test_zoo_is_exhaustive():
    """The matrix covers every config — a new config must pick a tier."""
    assert sorted(CAUSAL_ARCHS + ENCODER_ARCHS) == sorted(configs.ARCHS)
    for a in CAUSAL_ARCHS:
        assert configs.smoke_config(a).causal
    for a in ENCODER_ARCHS:
        assert not configs.smoke_config(a).has_decode


def _zoo_cfg(arch):
    cfg = configs.smoke_config(arch)
    kw = dict(dtype=jnp.float32, remat=False)
    if cfg.moe is not None:
        # expert capacity is batch-composition dependent: packed serving
        # and the b=1 oracle would drop different tokens at the default
        # factor, so give the smoke MoE room to route everything
        kw["moe"] = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    return dataclasses.replace(cfg, **kw)


def _params(cfg):
    from repro.models import lm
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    return params


def _reqs(cfg, lens=((12, 6), (7, 8), (9, 4))):
    rs = np.random.RandomState(0)
    return [(rs.randint(0, cfg.vocab_size, size=L).astype(np.int32), g)
            for L, g in lens]


def _contiguous_gen(cfg, params, prompt, max_new, max_len=32):
    """Single-sequence contiguous-cache greedy decode — the oracle."""
    from repro.runtime.steps import make_serve_steps
    arts = make_serve_steps(cfg, impl="xla", max_len=max_len, batch=1,
                            xla_chunk=16)
    caches = arts.cache_init_fn()
    logits, caches = arts.prefill_fn(params, jnp.asarray(prompt)[None],
                                     None, caches)
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
    out = [int(tok[0])]
    for i in range(max_new - 1):
        logits, caches = arts.decode_fn(params, tok, caches,
                                        jnp.int32(len(prompt) + i))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        out.append(int(tok[0]))
    return np.asarray(out, np.int32)


def _oracle(cfg, params, reqs):
    return {i: _contiguous_gen(cfg, params, p, g)
            for i, (p, g) in enumerate(reqs)}


def _check(out, expected, label):
    for rid, exp in expected.items():
        assert np.array_equal(out[rid], exp), \
            f"{label} request {rid}: engine {out[rid]} != oracle {exp}"


# ---------------------------------------------------------------------------
# eager: every causal config
# ---------------------------------------------------------------------------

@_zoo_param
def test_engine_matches_oracle_eager(arch):
    cfg = _zoo_cfg(arch)
    params = _params(cfg)
    reqs = _reqs(cfg)
    expected = _oracle(cfg, params, reqs)
    # pool fits ~2 of 3 requests → real admission waves for every family
    pcfg = PagedCacheConfig(page_size=8, num_pages=9, max_batch=2,
                            max_pages_per_seq=3)
    eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                        xla_chunk=16)
    out, stats = eng.run(reqs)
    _check(out, expected, f"{arch} eager")
    tables = eng.scheduler.tables
    assert tables.allocator.num_free == pcfg.num_pages - 1
    # recurrent-state slot conservation after the queue drains
    assert tables.state.num_occupied == 0
    assert tables.state.num_free == pcfg.max_batch
    if arch in RECURRENT_ARCHS:
        assert stats["state_releases"] == len(reqs)


# ---------------------------------------------------------------------------
# lazy + forced preemption: one config per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3_14b", "deepseek_moe_16b",
                                  "recurrentgemma_2b", "falcon_mamba_7b"])
def test_engine_matches_oracle_lazy_preempting(arch):
    """Pool tight enough that growth runs dry → a row is preempted, its
    pages AND its recurrent-state row are reclaimed (poisoned with 1e6),
    and the resumed sequence must still be token-identical — the snapshot/
    restore of recurrent state across preemption is exact."""
    cfg = _zoo_cfg(arch)
    params = _params(cfg)
    reqs = _reqs(cfg)
    expected = _oracle(cfg, params, reqs)
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_batch=2,
                            max_pages_per_seq=8)
    eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=32,
                        xla_chunk=16, lazy=True, poison_reclaimed=True)
    out, stats = eng.run(reqs)
    assert stats["preemptions"] >= 1             # the pressure actually bit
    _check(out, expected, f"{arch} lazy")
    if arch in RECURRENT_ARCHS:
        # every preemption released (and re-admitted) a state row on top
        # of the per-request release
        assert stats["state_releases"] == len(reqs) + stats["preemptions"]


# ---------------------------------------------------------------------------
# chunked prefill: the recurrent continuation path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["recurrentgemma_2b", "falcon_mamba_7b"])
def test_engine_matches_oracle_chunked_prefill(arch):
    """prefill_chunk < prompt length forces mid-prompt continuation spans:
    the conv tail and hidden state carried through StateCache rows between
    chunks must reproduce the one-shot prefill exactly."""
    cfg = _zoo_cfg(arch)
    params = _params(cfg)
    reqs = _reqs(cfg)
    expected = _oracle(cfg, params, reqs)
    pcfg = PagedCacheConfig(page_size=8, num_pages=9, max_batch=2,
                            max_pages_per_seq=3)
    eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                        xla_chunk=16, prefill_chunk=4)
    out, _ = eng.run(reqs)
    _check(out, expected, f"{arch} chunked")


# ---------------------------------------------------------------------------
# num_splits > 1 decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["granite_3_2b", "recurrentgemma_2b"])
def test_engine_matches_oracle_num_splits(arch):
    """Split-KV decode partitions the attention layers' KV walk; recurrent
    layers are untouched by it and must keep decoding correctly beside it."""
    cfg = _zoo_cfg(arch)
    params = _params(cfg)
    reqs = _reqs(cfg, lens=((12, 6), (7, 5)))
    expected = _oracle(cfg, params, reqs)
    pcfg = PagedCacheConfig(page_size=8, num_pages=9, max_batch=2,
                            max_pages_per_seq=3)
    eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                        xla_chunk=16, num_splits=2)
    out, _ = eng.run(reqs)
    _check(out, expected, f"{arch} num_splits=2")


# ---------------------------------------------------------------------------
# prefix sharing: attention-only, refused elsewhere
# ---------------------------------------------------------------------------

def test_prefix_sharing_matches_oracle_attention():
    cfg = _zoo_cfg("granite_3_2b")
    params = _params(cfg)
    rs = np.random.RandomState(1)
    system = rs.randint(0, cfg.vocab_size, size=8).astype(np.int32)
    # three requests over two admission waves: wave 1 prefills the shared
    # 2-page system prompt cold, wave 2's request hits the registered prefix
    tails = [rs.randint(0, cfg.vocab_size, size=L).astype(np.int32)
             for L in (4, 3, 4)]
    reqs = [(np.concatenate([system, t]), 5) for t in tails]
    expected = _oracle(cfg, params, reqs)
    pcfg = PagedCacheConfig(page_size=4, num_pages=17, max_batch=2,
                            max_pages_per_seq=6)
    eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                        xla_chunk=16, share_prefix=True)
    out, stats = eng.run(reqs)
    _check(out, expected, "granite_3_2b share_prefix")
    assert stats["prefill_tokens_skipped"] > 0   # the cache actually hit


@pytest.mark.parametrize("arch", ["recurrentgemma_2b", "falcon_mamba_7b"])
def test_prefix_sharing_refused_for_recurrent(arch):
    """The prefix index certifies cached KV *pages*; recurrent state is
    cumulative and unaddressable by content hash — the engine must refuse
    rather than silently serve wrong tokens."""
    cfg = _zoo_cfg(arch)
    params = _params(cfg)
    pcfg = PagedCacheConfig(page_size=8, num_pages=9, max_batch=2,
                            max_pages_per_seq=3)
    with pytest.raises(ValueError, match="attention-only"):
        ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                      share_prefix=True)


@pytest.mark.parametrize("arch", ["recurrentgemma_2b", "falcon_mamba_7b"])
def test_speculation_refused_for_recurrent(arch):
    """Rejected draft tokens would need recurrent-state rollback, which a
    cumulative scan state cannot do — refuse at construction."""
    cfg = _zoo_cfg(arch)
    params = _params(cfg)
    pcfg = PagedCacheConfig(page_size=8, num_pages=9, max_batch=2,
                            max_pages_per_seq=3)
    with pytest.raises(ValueError, match="attention-only"):
        ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                      speculate_k=4)


# ---------------------------------------------------------------------------
# encoder-only: the engine must refuse
# ---------------------------------------------------------------------------

def test_encoder_only_refused():
    cfg = _zoo_cfg("hubert_xlarge")
    params = _params(cfg)
    pcfg = PagedCacheConfig(page_size=8, num_pages=9, max_batch=2,
                            max_pages_per_seq=3)
    with pytest.raises(AssertionError, match="autoregressive"):
        ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24)
