"""Serving resilience + chaos harness: typed outcomes under injected faults.

The PR 10 contract, pinned here:

* every request the engine accepts terminates in exactly one typed outcome
  (``COMPLETED | CANCELLED | TIMEOUT | SHED | FAILED``) — no hangs, no
  silent disappearances, no engine-wide exceptions for one bad request;
* pool and state-row conservation hold after every drained run, whatever
  faults fired in between;
* rows a fault did not touch generate tokens bit-identical to a fault-free
  run (greedy decode is schedule-invariant per row);
* the same ``FaultPlan`` seed replays bit-identically;
* crash-at-step-N + host snapshot/restore resumes token-identically.

The fuzz matrix crosses seeded fault plans with {attention, recurrent}
configs × {eager, lazy, chunked prefill, speculation} — the same serving
feature matrix the conformance zoo pins fault-free.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.serving import (AdmissionImpossible, FaultEvent, FaultPlan,
                           InjectedCrash, Outcome, PagedCacheConfig, Request,
                           Scheduler, ServingEngine, untyped_rids)


def _cfg(arch="granite_3_2b"):
    cfg = configs.smoke_config(arch)
    kw = dict(dtype=jnp.float32, remat=False)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    return dataclasses.replace(cfg, **kw)


def _params(cfg):
    from repro.models import lm
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    return params


def _reqs(cfg, lens=((12, 6), (7, 8), (9, 4), (10, 5))):
    rs = np.random.RandomState(0)
    return [(rs.randint(0, cfg.vocab_size, size=L).astype(np.int32), g)
            for L, g in lens]


def _pcfg():
    return PagedCacheConfig(page_size=8, num_pages=11, max_batch=2,
                            max_pages_per_seq=3)


def _engine(cfg, params, pcfg=None, prefill_len=24, **kw):
    return ServingEngine(cfg, params=params, paged_cfg=pcfg or _pcfg(),
                         impl="xla", prefill_len=prefill_len, xla_chunk=16,
                         **kw)


def _check_drained(eng):
    """Conservation after the queue drains: every page and state row home."""
    alloc = eng.scheduler.tables.allocator
    assert alloc.num_allocated == 0
    assert alloc.num_free + alloc.num_cached == eng.pcfg.usable_pages
    st = eng.scheduler.tables.state
    assert st.num_occupied == 0 and st.num_free == st.capacity


def _outcomes(eng):
    return {rid: r.outcome for rid, r in eng.results.items()}


# ---------------------------------------------------------------------------
# outcome taxonomy on healthy runs
# ---------------------------------------------------------------------------

def test_plain_run_outcomes_all_completed():
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg)
    eng = _engine(cfg, params)
    out, stats = eng.run(reqs)
    assert untyped_rids(range(len(reqs)), eng.results) == []
    assert all(o is Outcome.COMPLETED for o in _outcomes(eng).values())
    assert stats["outcomes"]["completed"] == len(reqs)
    assert set(out) == set(range(len(reqs)))
    for rid, res in eng.results.items():
        assert np.array_equal(res.tokens, out[rid])
    _check_drained(eng)


# ---------------------------------------------------------------------------
# deadlines: wall-clock and engine-step budgets
# ---------------------------------------------------------------------------

def test_step_budget_timeout_partial_tokens():
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg, lens=((8, 12), (6, 12)))
    eng = _engine(cfg, params, max_steps=3)
    out, stats = eng.run(reqs)
    assert out == {}                       # nobody reached a 12-token budget
    assert all(o is Outcome.TIMEOUT for o in _outcomes(eng).values())
    assert stats["outcomes"]["timeout"] == 2
    # admitted at iter 0 (prefill token) + decodes at iters 1-2 → partial
    toks = eng.results[0].tokens
    assert 0 < len(toks) < 12
    assert "budget" in eng.results[0].reason
    _check_drained(eng)


def test_zero_wallclock_deadline_times_out_everything():
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg)
    eng = _engine(cfg, params, deadline_ms=0.0)
    out, _ = eng.run(reqs)
    assert out == {}
    assert all(o is Outcome.TIMEOUT for o in _outcomes(eng).values())
    assert all(len(r.tokens) == 0 for r in eng.results.values())
    _check_drained(eng)


def test_per_request_deadline_overrides_engine_default():
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg, lens=((8, 4), (6, 4)))
    eng = _engine(cfg, params)          # no engine-wide deadline
    eng.submit(reqs[0][0], reqs[0][1])
    eng.submit(reqs[1][0], reqs[1][1], max_steps=2)
    out, _ = eng.run()
    assert _outcomes(eng)[0] is Outcome.COMPLETED
    assert _outcomes(eng)[1] is Outcome.TIMEOUT
    assert list(out) == [0]
    _check_drained(eng)


# ---------------------------------------------------------------------------
# cancellation: waiting and mid-flight
# ---------------------------------------------------------------------------

def test_cancel_waiting_request():
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg)
    base, _ = _engine(cfg, params).run(reqs)
    eng = _engine(cfg, params)
    for p, g in reqs:
        eng.submit(p, g)
    assert eng.cancel(2)
    assert not eng.cancel(2)               # already terminated: no-op
    assert not eng.cancel(99)              # unknown rid: no-op, no raise
    out, _ = eng.run()
    assert _outcomes(eng)[2] is Outcome.CANCELLED
    assert len(eng.results[2].tokens) == 0
    for rid in (0, 1, 3):                  # survivors bit-identical
        assert np.array_equal(out[rid], base[rid])
    _check_drained(eng)


def test_cancel_active_via_fault_plan():
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg)
    base, _ = _engine(cfg, params).run(reqs)
    # cancel the lowest live rid (0: admitted in the first wave) at step 2
    plan = FaultPlan(seed=0, events=(FaultEvent(step=2, kind="cancel",
                                                arg=0),))
    eng = _engine(cfg, params, fault_plan=plan)
    out, stats = eng.run(reqs)
    assert _outcomes(eng)[0] is Outcome.CANCELLED
    assert 0 < len(eng.results[0].tokens) < len(base[0])  # partial kept
    assert stats["cancels"] == 1
    for rid in out:
        assert np.array_equal(out[rid], base[rid])
    assert untyped_rids(range(len(reqs)), eng.results) == []
    _check_drained(eng)


# ---------------------------------------------------------------------------
# backpressure: bounded queue + impossible-footprint shedding
# ---------------------------------------------------------------------------

def test_bounded_queue_sheds_newest():
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg)
    eng = _engine(cfg, params, max_queue=2)
    rids = [eng.submit(p, g) for p, g in reqs]
    assert rids == [0, 1, 2, 3]
    out, stats = eng.run()
    assert _outcomes(eng)[2] is Outcome.SHED
    assert _outcomes(eng)[3] is Outcome.SHED
    assert "queue full" in eng.results[3].reason
    assert stats["outcomes"]["shed"] == 2
    assert set(out) == {0, 1}
    _check_drained(eng)


def test_impossible_footprint_sheds_at_engine_submit():
    cfg = _cfg()
    params = _params(cfg)
    eng = _engine(cfg, params)
    # 3 pages/request pool (max_pages_per_seq=3): a 20+8 budget needs 4
    rid = eng.submit(np.arange(20, dtype=np.int32) % cfg.vocab_size, 8,
                     rid=7)
    assert rid == 7
    assert _outcomes(eng)[7] is Outcome.SHED
    assert "pool" in eng.results[7].reason or \
           "max_seq_len" in eng.results[7].reason
    out, _ = eng.run(_reqs(cfg, lens=((8, 4),)))
    assert set(out) == {8}                 # auto-rid continues past the shed
    _check_drained(eng)


def test_scheduler_footprint_raises_admission_impossible():
    # budget fits max_seq_len (28 <= 32) but needs 4 pages > 2 usable
    pcfg = PagedCacheConfig(page_size=8, num_pages=3, max_batch=1,
                            max_pages_per_seq=4)
    sched = Scheduler(pcfg)
    with pytest.raises(AdmissionImpossible, match="pool"):
        sched.submit(Request(rid=0, tokens=np.zeros(20, np.int32),
                             max_new_tokens=8))
    assert issubclass(AdmissionImpossible, ValueError)  # legacy pins hold


def test_window_relaxes_footprint_for_lazy_sliding_window():
    """The satellite-2 fix, capability direction: under lazy + sliding
    window (recurrentgemma, window 32) a request whose *full* budget can
    never sit in the pool at once is still admissible — only its O(window)
    tail is ever resident (dead-on-arrival blocks + reclamation) — and it
    must now be accepted at submit and served to completion, token-identical
    to a big-pool run.  Pre-fix, the token-count check shed it."""
    cfg = _cfg("recurrentgemma_2b")
    assert cfg.attn_window == 32
    params = _params(cfg)
    rs = np.random.RandomState(1)
    prompt = rs.randint(0, cfg.vocab_size, size=40).astype(np.int32)
    small = PagedCacheConfig(page_size=8, num_pages=8, max_batch=1,
                             max_pages_per_seq=8)
    # budget 40+17=57 → pages_for=8 > 7 usable; window tail 4+2=6 fits
    assert small.pages_for(57) > small.usable_pages
    big = PagedCacheConfig(page_size=8, num_pages=12, max_batch=1,
                           max_pages_per_seq=8)
    out_b, _ = _engine(cfg, params, pcfg=big, prefill_len=64,
                       lazy=True).run([(prompt, 17)])
    eng = _engine(cfg, params, pcfg=small, prefill_len=64, lazy=True)
    out_s, _ = eng.run([(prompt, 17)])
    assert _outcomes(eng)[0] is Outcome.COMPLETED
    assert np.array_equal(out_s[0], out_b[0])
    _check_drained(eng)
    # eager (full-footprint) still sheds it — the relaxation is window-only
    sched = Scheduler(small)
    with pytest.raises(AdmissionImpossible):
        sched.submit(Request(rid=0, tokens=prompt, max_new_tokens=17))


# ---------------------------------------------------------------------------
# health sentinel: NaN logits quarantine the row, not the batch
# ---------------------------------------------------------------------------

def test_nan_sentinel_quarantines_only_victim():
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg)
    base, _ = _engine(cfg, params).run(reqs)
    plan = FaultPlan(seed=0, events=(FaultEvent(step=2, kind="nan", arg=0),))
    eng = _engine(cfg, params, fault_plan=plan)
    out, stats = eng.run(reqs)
    # victim: lowest consumed slot at step 2 = slot 0 = rid 0 (no churn)
    assert _outcomes(eng)[0] is Outcome.FAILED
    assert "sentinel" in eng.results[0].reason
    assert stats["outcomes"]["failed"] == 1
    for rid in out:                        # batch-mates bit-identical
        assert np.array_equal(out[rid], base[rid])
    assert len(out) == len(reqs) - 1
    assert untyped_rids(range(len(reqs)), eng.results) == []
    _check_drained(eng)


# ---------------------------------------------------------------------------
# livelock watchdog: wedged states drain instead of hanging/raising
# ---------------------------------------------------------------------------

def test_unservable_request_fails_typed_not_engine_wide():
    """A request whose admission can never succeed (white-boxed past submit
    validation) used to raise RuntimeError and take the whole batch down;
    now it fails typed and its batch-mates complete."""
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg, lens=((8, 4), (6, 4)))
    # 2 usable pages: normal budgets need 2, the white-boxed one needs 3
    pcfg = PagedCacheConfig(page_size=8, num_pages=3, max_batch=2,
                            max_pages_per_seq=3)
    base, _ = _engine(cfg, params, pcfg=pcfg).run(reqs)
    eng = _engine(cfg, params, pcfg=pcfg)
    eng.scheduler.waiting.append(
        Request(rid=99, tokens=np.zeros(20, np.int32), max_new_tokens=4))
    out, _ = eng.run(reqs)
    assert _outcomes(eng)[99] is Outcome.FAILED
    assert "stuck" in eng.results[99].reason
    for rid in (0, 1):
        assert _outcomes(eng)[rid] is Outcome.COMPLETED
        assert np.array_equal(out[rid], base[rid])
    _check_drained(eng)


def test_permanent_pool_exhaustion_drains_all_failed():
    """An exhaust fault that never returns its pages: every request must
    terminate typed (FAILED via the stuck path) — no hang, and the pocket
    is surrendered at exit so conservation still holds."""
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg)
    plan = FaultPlan(seed=0, events=(FaultEvent(step=0, kind="exhaust"),),
                     pocket_hold=1 << 30)
    eng = _engine(cfg, params, fault_plan=plan)
    out, stats = eng.run(reqs)
    assert out == {}
    assert all(o is Outcome.FAILED for o in _outcomes(eng).values())
    assert untyped_rids(range(len(reqs)), eng.results) == []
    assert stats["outcomes"]["failed"] == len(reqs)
    _check_drained(eng)


# ---------------------------------------------------------------------------
# fault-plan determinism + crash/snapshot/restore
# ---------------------------------------------------------------------------

def test_fault_plan_seed_replay_is_bit_identical():
    a, b = FaultPlan(seed=5), FaultPlan(seed=5)
    assert a.events == b.events and a.describe() == b.describe()
    assert FaultPlan(seed=6).events != a.events
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan(kinds=("segfault",))


def test_engine_replay_same_seed_same_everything():
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg)
    runs = []
    for _ in range(2):
        eng = _engine(cfg, params, fault_plan=FaultPlan(seed=11, horizon=16))
        out, _ = eng.run(reqs)
        runs.append((_outcomes(eng), out))
        _check_drained(eng)
    assert runs[0][0] == runs[1][0]
    assert set(runs[0][1]) == set(runs[1][1])
    for rid in runs[0][1]:
        assert np.array_equal(runs[0][1][rid], runs[1][1][rid])


def test_crash_snapshot_restore_resumes_token_identical():
    cfg = _cfg()
    params = _params(cfg)
    reqs = _reqs(cfg)
    base, _ = _engine(cfg, params).run(reqs)
    plan = FaultPlan(seed=0, events=(), crash_step=3)
    eng = _engine(cfg, params, fault_plan=plan)
    with pytest.raises(InjectedCrash):
        eng.run(reqs)
    snap = eng.snapshot()
    alloc = eng.scheduler.tables.allocator   # crash leaked nothing
    assert alloc.num_free + alloc.num_cached + alloc.num_allocated \
        == eng.pcfg.usable_pages
    eng2 = _engine(cfg, params)
    eng2.restore(snap)
    out, _ = eng2.run()
    assert set(out) == set(base)
    for rid in base:
        assert np.array_equal(out[rid], base[rid]), \
            f"rid {rid} diverged across crash/restore"
    _check_drained(eng2)
    # restoring the same snapshot again must work (snapshots are immutable)
    eng3 = _engine(cfg, params)
    eng3.restore(snap)
    out3, _ = eng3.run()
    assert all(np.array_equal(out3[rid], base[rid]) for rid in base)


# ---------------------------------------------------------------------------
# the chaos fuzz matrix: seeded plans × configs × serving modes
# ---------------------------------------------------------------------------

_MODES = {
    "eager": {},
    "lazy": {"lazy": True},
    "chunked": {"prefill_chunk": 6},
    "spec": {"speculate_k": 2},
}
_CELLS = ([("granite_3_2b", m) for m in ("eager", "lazy", "chunked", "spec")]
          + [("falcon_mamba_7b", m) for m in ("eager", "lazy")])


@pytest.mark.parametrize("arch,mode", _CELLS,
                         ids=[f"{a}-{m}" for a, m in _CELLS])
def test_chaos_fuzz_matrix(arch, mode):
    """Seeded faults across the serving feature matrix: the run returns
    (no hang — the watchdog bounds every wedge), every rid terminates
    typed, conservation holds, and completed rows are bit-identical to the
    fault-free run of the same mode."""
    cfg = _cfg(arch)
    params = _params(cfg)
    reqs = _reqs(cfg)
    kw = dict(_MODES[mode])
    if mode == "lazy":
        pcfg = PagedCacheConfig(page_size=4, num_pages=10, max_batch=2,
                                max_pages_per_seq=8)
        prefill_len = 32
    else:
        pcfg, prefill_len = _pcfg(), 24
    eng0 = _engine(cfg, params, pcfg=pcfg, prefill_len=prefill_len, **kw)
    base, _ = eng0.run(list(reqs))
    _check_drained(eng0)
    assert len(base) == len(reqs)

    seed = 13 + len(mode) + len(arch)      # vary plans across cells
    eng = _engine(cfg, params, pcfg=pcfg, prefill_len=prefill_len,
                  fault_plan=FaultPlan(seed=seed, horizon=24), **kw)
    out, stats = eng.run(list(reqs))
    assert untyped_rids(range(len(reqs)), eng.results) == [], \
        f"{arch}/{mode}: untyped outcomes"
    assert sum(stats["outcomes"].values()) == len(reqs)
    for rid, toks in out.items():
        assert np.array_equal(toks, base[rid]), \
            f"{arch}/{mode}: completed rid {rid} diverged under faults"
    _check_drained(eng)
