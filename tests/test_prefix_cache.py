"""Prefix caching + copy-on-write pages + chunked prefill.

The load-bearing contracts:
* allocator safety: double frees and trash frees raise (a page freed twice
  used to be handed to two slots, silently aliasing their KV); refcounts
  track block-table aliases exactly; retained ref-0 pages park in a cached
  LRU ring and are revived by hits or evicted (with the index notified)
  when the free list runs dry;
* the prefix index chains digests, so a block hit certifies the whole
  prefix through that block — equal tokens at equal absolute positions;
* admission aliases matched blocks onto existing pages (capped one token
  short of the full prompt, so prefill always emits last-token logits) and
  the first divergent write to a shared page copy-on-writes it;
* conservation under randomized admit/grow/preempt/reclaim/release churn:
  free + cached + allocated == usable, refcounts == ownership entries,
  trash pages never owned — with and without sharing;
* scheduler-level validation: empty prompts, duplicate rids and
  never-admissible budgets are rejected at submit (direct scheduler users
  used to be able to queue a request that deadlocks the serve loop);
* end to end: a shared-prefix trace generates bit-identically to the
  no-sharing engine while processing fewer prefill tokens and allocating
  fewer pages — including under lazy admission with forced preemption and
  poisoned reclaimed pages — and chunked prefill (budget < prompt_len) is
  token-identical to unchunked.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (BlockTables, PageAllocator, PagedCacheConfig,
                           PrefixIndex, Request, Scheduler, TRASH_PAGE)


# ---------------------------------------------------------------------------
# allocator: refcounts, double-free guard, cached ring
# ---------------------------------------------------------------------------

def test_allocator_double_free_raises():
    """The silent-corruption bug: freeing a page twice used to hand it to
    two slots.  Now every page carries a refcount and over-freeing raises."""
    a = PageAllocator(num_pages=6)
    got = a.alloc(2)
    a.free(got)
    with pytest.raises(ValueError):
        a.free([got[0]])                      # double free
    with pytest.raises(ValueError):
        a.free([TRASH_PAGE])                  # trash is never allocated
    with pytest.raises(ValueError):
        a.free([5])                           # never handed out at all


def test_allocator_refcounts_and_shared_free():
    a = PageAllocator(num_pages=6)
    (p,) = a.alloc(1)
    assert a.refcount(p) == 1
    a.share(p)
    a.share(p)
    assert a.refcount(p) == 3 and a.refs_total == 3
    assert a.free([p]) == [] and a.refcount(p) == 2   # alias dropped, alive
    assert a.free([p]) == []
    assert a.free([p]) == [p]                 # last reference frees for real
    with pytest.raises(ValueError):
        a.free([p])
    with pytest.raises(ValueError):
        a.share(p)                            # free pages cannot be shared


def test_allocator_cached_ring_revival_and_lru_eviction():
    evicted = []
    a = PageAllocator(num_pages=6)            # pages 1..5
    a.on_evict = evicted.append
    got = a.alloc(3)                          # 1, 2, 3
    a.free([got[0]], retain=frozenset([got[0]]))     # park 1 (oldest)
    a.free([got[1]], retain=frozenset([got[1]]))     # park 2
    assert a.num_free == 2 and a.num_cached == 2 and a.num_allocated == 1
    a.share(got[1])                           # prefix hit revives page 2
    assert a.revivals == 1 and a.num_cached == 1 and a.refcount(got[1]) == 1
    # alloc beyond the free list: the LRU cached page is evicted, hook fires
    pages = a.alloc(3)
    assert pages is not None and got[0] in pages and evicted == [got[0]]
    assert a.num_free == 0 and a.num_cached == 0
    assert a.alloc(1) is None                 # nothing left, no side effect
    # conservation at every point above: free + cached + allocated == 5
    assert a.num_free + a.num_cached + a.num_allocated == 5


# ---------------------------------------------------------------------------
# prefix index: chained digests
# ---------------------------------------------------------------------------

def test_prefix_index_chained_digests():
    idx = PrefixIndex(page_size=4)
    a = np.arange(10, dtype=np.int32)               # blocks: 4, 4, partial 2
    b = np.concatenate([a[:8], [99, 9]]).astype(np.int32)
    da, db = idx.block_digests(a), idx.block_digests(b)
    assert len(da) == 3
    assert da[0] == db[0] and da[1] == db[1]        # shared full blocks
    assert da[2] != db[2]                           # tails differ
    # chaining: a different *first* block changes every later digest even
    # when the later tokens are identical
    c = np.concatenate([[77], a[1:]]).astype(np.int32)
    dc = idx.block_digests(c)
    assert dc[1] != da[1] and dc[2] != da[2]
    # a shorter identical tail hashes differently from a longer one
    assert idx.block_digests(a[:9])[2] != da[2]
    # register / lookup / forget round-trip; first writer wins
    assert idx.register(da[0], 7)
    assert not idx.register(da[0], 8)               # digest taken
    assert not idx.register(da[1], 7)               # page taken
    assert idx.lookup(da[0]) == 7 and idx.registered(7)
    idx.forget(7)
    assert idx.lookup(da[0]) is None and len(idx) == 0


# ---------------------------------------------------------------------------
# block tables: admission sharing + copy-on-write
# ---------------------------------------------------------------------------

def _shared_tables():
    cfg = PagedCacheConfig(page_size=4, num_pages=17, max_batch=3,
                           max_pages_per_seq=4)
    return cfg, BlockTables(cfg, share_prefix=True)


def test_admit_shares_matched_blocks_and_caps_last_token():
    cfg, bt = _shared_tables()
    prompt = np.arange(12, dtype=np.int32)          # 3 full blocks
    assert bt.admit(0, n_tokens=12, tokens=prompt)
    assert bt.hist[0] == 0                          # cold index: no match
    bt.kv_len[0] = 12
    bt.register_prefilled(0, 12)
    # identical prompt: all 3 blocks match, but the match is capped at 11
    # tokens so prefill still emits the last token's logits
    assert bt.admit(1, n_tokens=12, tokens=prompt)
    assert bt.hist[1] == 11
    assert np.array_equal(bt.tables[1, :3], bt.tables[0, :3])
    assert all(bt.allocator.refcount(int(p)) == 2 for p in bt.tables[0, :3])
    # slot 1's write block (token 11 → block 2) is shared → COW
    free_before = bt.allocator.num_free
    assert bt.prepare_write(1)
    assert bt.tables[1, 2] != bt.tables[0, 2]       # rewritten to a fresh page
    assert bt.allocator.refcount(int(bt.tables[0, 2])) == 1
    assert bt.cow_copies == 1 and bt.allocator.num_free == free_before - 1
    pairs = bt.drain_copies()
    assert pairs == [(int(bt.tables[0, 2]), int(bt.tables[1, 2]))]
    assert bt.drain_copies() == []                  # drained exactly once
    # a diverging prompt shares only the common full blocks
    other = np.concatenate([prompt[:8], [77, 78, 79, 80]]).astype(np.int32)
    assert bt.admit(2, n_tokens=12, tokens=other)
    assert bt.hist[2] == 8
    assert np.array_equal(bt.tables[2, :2], bt.tables[0, :2])
    assert bt.tables[2, 2] not in (bt.tables[0, 2], bt.tables[1, 2])
    # conservation with sharing: refcounts == ownership entries
    owned_entries = sum(len(m) for m in bt._owned.values())
    assert bt.allocator.refs_total == owned_entries


def test_release_retains_indexed_pages_for_revival():
    cfg, bt = _shared_tables()
    prompt = np.arange(12, dtype=np.int32)
    assert bt.admit(0, n_tokens=12, tokens=prompt)
    bt.kv_len[0] = 12
    bt.register_prefilled(0, 12)
    pages = [int(p) for p in bt.tables[0, :3]]
    assert bt.release(0) == []                      # indexed → cached, not freed
    assert bt.allocator.num_cached == 3
    # the next identical prompt revives the cached pages without compute
    assert bt.admit(1, n_tokens=12, tokens=prompt)
    assert bt.hist[1] == 11 and [int(p) for p in bt.tables[1, :3]] == pages
    assert bt.allocator.revivals == 3 and bt.allocator.num_cached == 0


# ---------------------------------------------------------------------------
# randomized conservation fuzz (satellite: scheduler/allocator invariants)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("share", [False, True])
def test_randomized_conservation(share):
    """Random admit / multi-token grow (speculative lookahead + partial
    acceptance rollback) / decode / preempt / reclaim / release churn keeps
    the pool conserved: free + cached + allocated == usable pages,
    refcounts == block-table ownership entries, trash pages never owned,
    and without sharing no page backs two table entries."""
    cfg = PagedCacheConfig(page_size=4, num_pages=12, max_batch=3,
                           max_pages_per_seq=5)
    rs = np.random.RandomState(7)
    sched = Scheduler(cfg, lazy=True, share_prefix=share)
    alloc = sched.tables.allocator
    # a small prompt vocabulary makes repeated prefixes (and so sharing,
    # retention and revival) actually happen
    prompts = [rs.randint(0, 5, size=n).astype(np.int32)
               for n in (4, 7, 9, 12)]
    next_rid = 0

    def check():
        tables = sched.tables
        owned_pages = [p for m in tables._owned.values() for p in m.values()]
        assert alloc.num_free + alloc.num_cached + alloc.num_allocated \
            == cfg.usable_pages
        assert alloc.refs_total == len(owned_pages)
        assert not (set(owned_pages) & set(cfg.trash_pages))
        if not share:
            assert len(owned_pages) == len(set(owned_pages))
        for slot, m in tables._owned.items():
            for blk, page in m.items():
                assert tables.tables[slot, blk] == page

    for step in range(400):
        op = rs.randint(5)
        if op == 0 and len(sched.waiting) < 4:
            p = prompts[rs.randint(len(prompts))]
            sched.submit(Request(rid=next_rid, tokens=p.copy(),
                                 max_new_tokens=int(rs.randint(1, 6))))
            next_rid += 1
        elif op == 1:
            for seq in sched.admit():
                # emulate the engine: the prompt becomes resident
                seq.prefilled = seq.request.prompt_len
                sched.tables.kv_len[seq.slot] = seq.request.prompt_len
                sched.tables.register_prefilled(seq.slot, seq.prefilled)
                seq.generated.append(int(rs.randint(5)))
        elif op == 2 and sched.active:
            # speculative lookahead: grow up to `look` positions at once,
            # then advance each surviving row by a random accepted count
            # m <= look — the un-advanced remainder is the rolled-back
            # draft, whose already-granted pages must stay owned (reused by
            # the next step) without ever double-allocating
            look = int(rs.randint(1, 6))
            sched.ensure_growth(look)
            sched.tables.drain_copies()
            for seq in list(sched.active.values()):
                if seq.prefilling or seq.done:
                    continue
                room = seq.request.max_new_tokens - len(seq.generated)
                m = int(rs.randint(1, min(look, room) + 1))
                if sched.tables.append_dest_ok(seq.slot, m):
                    sched.tables.kv_len[seq.slot] += m
                    seq.generated.extend(
                        int(rs.randint(5)) for _ in range(m))
        elif op == 3 and sched.active:
            for slot in list(sched.active):
                sched.tables.reclaim_out_of_window(slot, window=6)
        elif op == 4:
            sched.evict_finished()
        check()
    # drain: release everything; cached pages are the only residue
    for seq in list(sched.active.values()):
        sched.preempt(seq)
    check()
    assert alloc.num_allocated == 0
    assert alloc.num_free + alloc.num_cached == cfg.usable_pages
    if not share:
        assert alloc.num_cached == 0


# ---------------------------------------------------------------------------
# scheduler submit validation (satellites: moved checks + duplicate rids)
# ---------------------------------------------------------------------------

def test_scheduler_submit_validation():
    cfg = PagedCacheConfig(page_size=4, num_pages=6, max_batch=2,
                           max_pages_per_seq=8)     # 5 usable pages, wide rows
    sched = Scheduler(cfg)
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit(Request(rid=0, tokens=np.zeros(0, np.int32),
                             max_new_tokens=2))
    # fits max_seq_len (32) but not the pool (needs 6 > 5 usable pages):
    # used to be accepted and spin the serve loop forever
    with pytest.raises(ValueError, match="pool"):
        sched.submit(Request(rid=0, tokens=np.zeros(20, np.int32),
                             max_new_tokens=4))
    sched.submit(Request(rid=0, tokens=np.zeros(4, np.int32),
                         max_new_tokens=2))
    with pytest.raises(ValueError, match="already submitted"):
        sched.submit(Request(rid=0, tokens=np.zeros(4, np.int32),
                             max_new_tokens=2))
    sched.submit(Request(rid=1, tokens=np.zeros(4, np.int32),
                         max_new_tokens=2))         # fresh rid still fine


# ---------------------------------------------------------------------------
# end to end (jitted smoke model)
# ---------------------------------------------------------------------------

def _smoke_cfg():
    from repro import configs
    return dataclasses.replace(configs.smoke_config("qwen3_14b"),
                               dtype=jnp.float32, remat=False)


def _shared_prefix_trace(cfg, rs):
    """Wave 1 (cold): a prompt and a same-prefix sibling.  Wave 2: two exact
    duplicates of the first prompt — admitted together they alias the same
    blocks at refcount 2, so the first one's write COWs."""
    prefix = rs.randint(0, cfg.vocab_size, size=9).astype(np.int32)
    suf_a = rs.randint(0, cfg.vocab_size, size=3).astype(np.int32)
    suf_b = rs.randint(0, cfg.vocab_size, size=3).astype(np.int32)
    cold = np.concatenate([prefix, suf_a])
    return [(cold, 4), (np.concatenate([prefix, suf_b]), 4),
            (cold.copy(), 4), (cold.copy(), 4)]


def test_engine_duplicate_rid_rejected():
    from repro.models import lm
    from repro.serving import ServingEngine

    cfg = _smoke_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    pcfg = PagedCacheConfig(page_size=8, num_pages=8, max_batch=2,
                            max_pages_per_seq=3)
    eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=16)
    prompt = np.arange(4, dtype=np.int32)
    assert eng.submit(prompt, 2, rid=5) == 5
    with pytest.raises(ValueError, match="already submitted"):
        eng.submit(prompt, 2, rid=5)                # caller-supplied dup
    assert eng.submit(prompt, 2) == 6               # auto rids skip past it


def test_engine_prefix_sharing_matches_and_skips_work():
    """A shared-prefix trace under share_prefix=True generates bit-identically
    to the no-sharing engine while prefilling fewer tokens and allocating
    fewer pages; the exact-duplicate prompt exercises full-match capping and
    the COW of its shared write block."""
    from repro.models import lm
    from repro.serving import ServingEngine

    cfg = _smoke_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _shared_prefix_trace(cfg, np.random.RandomState(2))
    pcfg = PagedCacheConfig(page_size=4, num_pages=25, max_batch=2,
                            max_pages_per_seq=5)

    def run(**kw):
        eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=16,
                            xla_chunk=16, **kw)
        out, stats = eng.run(list(reqs))
        alloc = eng.scheduler.tables.allocator
        # drained: nothing allocated; only index-retained pages linger
        assert alloc.num_allocated == 0
        assert alloc.num_free + alloc.num_cached == pcfg.usable_pages
        return out, stats

    out_ref, st_ref = run()
    out_sh, st_sh = run(share_prefix=True)
    assert set(out_ref) == set(out_sh)
    for rid in out_ref:
        assert np.array_equal(out_sh[rid], out_ref[rid]), \
            f"request {rid}: shared {out_sh[rid]} != baseline {out_ref[rid]}"
    # reuse actually happened, proportionally to the shared prefix: wave 1
    # is cold (index empty), each wave-2 duplicate of the 12-token prompt
    # skips all but its final token and aliases all 3 prompt blocks
    assert st_ref["prefill_tokens_skipped"] == 0
    assert st_sh["prefill_tokens_skipped"] == 11 + 11
    assert st_sh["prefill_tokens"] \
        == st_ref["prefill_tokens"] - st_sh["prefill_tokens_skipped"]
    assert st_sh["pages_shared"] == 3 + 3
    assert st_sh["pages_allocated"] < st_ref["pages_allocated"]
    # the duplicates' write block (token 11) lands in a block both alias at
    # refcount 2: the first writer COWs, the second then owns it exclusively
    assert st_sh["cow_copies"] == 1
    assert st_sh["pages_grown"] == st_ref["pages_grown"]


def test_engine_sharing_lazy_preempt_poison_identical():
    """Sharing composes with the whole pressure stack: lazy admission over a
    pool tight enough to force preemptions, sliding-window reclamation with
    poisoned freed pages, and prefix revival of a finished request's pages.
    Generations must stay bit-identical to the unshared engine."""
    from repro.models import lm
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(_smoke_cfg(), attn_window=10)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    rs = np.random.RandomState(4)
    prefix = rs.randint(0, cfg.vocab_size, size=8).astype(np.int32)
    reqs = [(np.concatenate([prefix, rs.randint(
        0, cfg.vocab_size, size=n).astype(np.int32)]), g)
        for n, g in [(3, 9), (1, 7), (3, 8)]]
    # 6 usable pages: wave 1's two prompts reserve all of them, so the first
    # page-boundary crossing before reclamation catches up must preempt
    pcfg = PagedCacheConfig(page_size=4, num_pages=7, max_batch=2,
                            max_pages_per_seq=6)

    def run(**kw):
        eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                            xla_chunk=16, lazy=True, poison_reclaimed=True,
                            **kw)
        return eng.run(list(reqs))

    out_ref, st_ref = run()
    out_sh, st_sh = run(share_prefix=True)
    assert st_sh["preemptions"] >= 1            # pressure bit with sharing on
    assert st_sh["pages_reclaimed"] > 0
    assert st_sh["prefill_tokens_skipped"] > 0  # ...and sharing still engaged
    assert set(out_ref) == set(out_sh)
    for rid in out_ref:
        assert np.array_equal(out_sh[rid], out_ref[rid]), \
            f"request {rid}: shared {out_sh[rid]} != baseline {out_ref[rid]}"


def test_engine_chunked_prefill_token_identical():
    """prefill_chunk < prompt_len splits prompts into spans interleaved with
    decode steps; greedy generations match the unchunked engine exactly, and
    the long prompt visibly overlaps other rows' decoding."""
    from repro.models import lm
    from repro.serving import ServingEngine

    cfg = _smoke_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    rs = np.random.RandomState(5)
    reqs = [(rs.randint(0, cfg.vocab_size, size=14).astype(np.int32), 5),
            (rs.randint(0, cfg.vocab_size, size=4).astype(np.int32), 7),
            (rs.randint(0, cfg.vocab_size, size=9).astype(np.int32), 3)]
    pcfg = PagedCacheConfig(page_size=4, num_pages=20, max_batch=3,
                            max_pages_per_seq=5)

    def run(**kw):
        eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=16,
                            xla_chunk=16, **kw)
        return eng.run(list(reqs))

    out_ref, st_ref = run()
    out_ch, st_ch = run(prefill_chunk=5)
    assert st_ch["prefill_tokens"] == st_ref["prefill_tokens"] == 14 + 4 + 9
    assert set(out_ref) == set(out_ch)
    for rid in out_ref:
        assert np.array_equal(out_ch[rid], out_ref[rid]), \
            f"request {rid}: chunked {out_ch[rid]} != unchunked {out_ref[rid]}"
    # chunking + sharing compose: the same trace, both features on
    out_both, st_both = run(prefill_chunk=5, share_prefix=True)
    for rid in out_ref:
        assert np.array_equal(out_both[rid], out_ref[rid])
