"""Dropout RNG determinism: forward/backward replay + golden regression.

The fused kernels never store dropout masks — the backward *regenerates* them
from element coordinates (kernels/rng.py). That contract needs two guards:

1. replay determinism: the same (seed, b, h, q, k) coordinates produce
   bitwise-identical masks everywhere they are evaluated (fwd kernel, both bwd
   kernels, the XLA scan, the naive oracle).
2. a golden-value regression: the generator is part of the checkpoint-
   compatibility surface (a silent change re-randomises every resumed run's
   dropout stream), so fixed coordinates must hash to fixed bits forever.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_qkv, max_err
from repro.kernels import rng
from repro.kernels.flash_fwd import flash_fwd
from repro.kernels.flash_bwd import flash_bwd
from repro.core.attention import spark_attention


def test_mask_bitwise_replay_across_evaluations():
    """Same coordinates → bitwise-identical masks, under jit and not."""
    qp = jnp.arange(64, dtype=jnp.int32)[:, None]
    kp = jnp.arange(64, dtype=jnp.int32)[None, :]
    m1 = rng.dropout_keep_mask(0.3, 9, 2, 5, qp, kp)
    m2 = rng.dropout_keep_mask(0.3, 9, 2, 5, qp, kp)
    m3 = jax.jit(lambda: rng.dropout_keep_mask(0.3, 9, 2, 5, qp, kp))()
    assert bool(jnp.all(m1 == m2)) and bool(jnp.all(m1 == m3))


def test_fwd_and_bwd_recompute_identical_masks(rng_key):
    """The backward's recomputed keep-mask equals the forward's bit-for-bit:
    with dropout active, flash_bwd(dO=0 except one row) must produce gradients
    consistent with a finite-difference of the flash_fwd loss — only true if
    both passes see the same mask. Checked across every (b, h) plane."""
    b, h, s, d = 2, 3, 32, 32
    q, k, v, do = make_qkv(rng_key, b, h, h, s, s, d)
    cfgkw = dict(dropout_rate=0.35, dropout_seed=123, block_q=16, block_kv=16,
                 interpret=True)
    o, lse = flash_fwd(q, k, v, **cfgkw)
    dq, dk, dv = flash_bwd(q, k, v, o, lse, do, **cfgkw)

    def loss(q_):
        o_, _ = flash_fwd(q_, k, v, **cfgkw)
        return float((o_ * do).sum())

    eps = 1e-3
    bi, hi = 1, 2  # a non-zero (b, h) plane: the mask hash folds both indices
    e = jnp.zeros_like(q).at[bi, hi, 5, 7].set(eps)
    fd = (loss(q + e) - loss(q - e)) / (2 * eps)
    g = float(dq[bi, hi, 5, 7])
    assert abs(fd - g) < 5e-2, (bi, hi, fd, g)


def test_mask_identical_across_all_impls(rng_key):
    """All four impls consume the same coordinate-hash mask → identical
    dropped outputs (not just statistically similar)."""
    q, k, v, _ = make_qkv(rng_key, 1, 2, 2, 64, 64, 32)
    outs = [spark_attention(q, k, v, impl=impl, dropout_rate=0.4, seed=77,
                            block_q=32, block_kv=32, xla_chunk=32)
            for impl in ("naive", "xla", "pallas_interpret")]
    assert max_err(outs[0], outs[1]) < 1e-5
    assert max_err(outs[0], outs[2]) < 1e-5


# ---------------------------------------------------------------------------
# golden regression: these literals pin the generator's output. If this test
# fails you have CHANGED THE RNG — every checkpointed run's dropout stream
# silently re-randomises on resume. Bump deliberately or revert.
# ---------------------------------------------------------------------------

GOLDEN_BITS_ROW0 = [0x2573FE71, 0x84EF34C3, 0x73D812D0, 0x617B245F,
                    0xEA793DC6, 0xA1C95254, 0x78A56FB9, 0xCEB20E90]
GOLDEN_BITS_ROW7 = [0xE87F66D4, 0xD78E4081, 0x05ABACC8, 0x7758B7FA,
                    0xBE9F5D74, 0xAD295C7C, 0x867EEC7F, 0xA46E6A33]
# keep-mask rows (rate=0.25, seed=42, b=1, h=3) packed as 8-bit integers
GOLDEN_MASK_PACKED = [127, 204, 151, 223, 221, 215, 255, 223]


def test_golden_random_bits():
    qp = jnp.arange(8, dtype=jnp.int32)[:, None]
    kp = jnp.arange(8, dtype=jnp.int32)[None, :]
    bits = np.asarray(rng.random_bits(42, 1, 3, qp, kp))
    assert [int(x) for x in bits[0]] == GOLDEN_BITS_ROW0
    assert [int(x) for x in bits[7]] == GOLDEN_BITS_ROW7


def test_golden_keep_mask():
    qp = jnp.arange(8, dtype=jnp.int32)[:, None]
    kp = jnp.arange(8, dtype=jnp.int32)[None, :]
    m = np.asarray(rng.dropout_keep_mask(0.25, 42, 1, 3, qp, kp))
    packed = [int("".join(str(int(b)) for b in row), 2) for row in m]
    assert packed == GOLDEN_MASK_PACKED
