"""Property tests for the system's core invariants.

The online-softmax state algebra (core/online_softmax.py) is the single piece
of math every execution path shares — kernel, XLA fallback, distributed decode
merge. If its invariants hold, block decomposition is sound everywhere.

``hypothesis`` is optional: when it is installed the invariants are fuzzed;
when it is absent the same invariants run over a fixed deterministic case grid
(so the tier-1 suite still collects and still asserts the algebra).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import online_softmax as osm
from repro.kernels import rng

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
except ImportError:  # CI installs hypothesis; bare containers may not have it
    HAS_HYPOTHESIS = False

    def given(*_a, **_k):  # keep the decorated definitions importable
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(deterministic fallback tests below)")

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

    def score_blocks():
        return None


def _softmax_weighted(s, v):
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return p @ v


def _case(seed, rows, cols, n_blocks, d, scale):
    r = np.random.RandomState(seed)
    s = (r.randn(rows, n_blocks * cols) * scale).astype(np.float32)
    v = r.randn(n_blocks * cols, d).astype(np.float32)
    return s, v, cols


# deterministic grid used when hypothesis is unavailable (and cheap enough to
# always run as a smoke layer): (seed, rows, cols, n_blocks, d, scale)
DET_CASES = [
    (0, 2, 2, 1, 1, 1.0),
    (1, 4, 8, 3, 4, 0.5),
    (2, 8, 16, 4, 8, 30.0),   # large-magnitude scores
    (3, 3, 5, 2, 7, 10.0),    # odd sizes
    (4, 8, 4, 4, 2, 0.1),
]


if HAS_HYPOTHESIS:
    @st.composite
    def score_blocks(draw):
        rows = draw(st.integers(2, 8))
        cols = draw(st.integers(2, 16))
        n_blocks = draw(st.integers(1, 4))
        d = draw(st.integers(1, 8))
        seed = draw(st.integers(0, 2**31 - 1))
        scale = draw(st.floats(0.1, 30.0))  # exercise large-magnitude scores
        return _case(seed, rows, cols, n_blocks, d, scale)


# ---------------------------------------------------------------------------
# invariant checkers (shared by the fuzzed and deterministic variants)
# ---------------------------------------------------------------------------

def check_blocked_equals_full_softmax(data):
    """Folding blocks sequentially == softmax over the concatenation (Eq. 3)."""
    s, v, cols = data
    rows, total = s.shape
    d = v.shape[1]
    state = osm.init_state((rows,), d)
    for i in range(total // cols):
        state = osm.update(state, jnp.asarray(s[:, i * cols:(i + 1) * cols]),
                           jnp.asarray(v[i * cols:(i + 1) * cols]))
    o, lse = osm.finalize(state)
    o_ref = _softmax_weighted(s, v)
    np.testing.assert_allclose(np.asarray(o), o_ref, atol=1e-4, rtol=1e-4)
    # lse is the true log-sum-exp
    lse_ref = np.log(np.exp(s - s.max(-1, keepdims=True)).sum(-1)) + s.max(-1)
    np.testing.assert_allclose(np.asarray(lse), lse_ref, atol=1e-4, rtol=1e-4)


def check_merge_is_order_invariant(data):
    """State merge is commutative+associative → kv blocks can be processed in
    any order (this is what licenses the distributed flash-decode merge)."""
    s, v, cols = data
    rows, total = s.shape
    d = v.shape[1]
    n = total // cols
    states = []
    for i in range(n):
        st_i = osm.init_state((rows,), d)
        st_i = osm.update(st_i, jnp.asarray(s[:, i * cols:(i + 1) * cols]),
                          jnp.asarray(v[i * cols:(i + 1) * cols]))
        states.append(st_i)
    fwd = states[0]
    for st_i in states[1:]:
        fwd = osm.merge(fwd, st_i)
    rev = states[-1]
    for st_i in reversed(states[:-1]):
        rev = osm.merge(rev, st_i)
    o1, l1 = osm.finalize(fwd)
    o2, l2 = osm.finalize(rev)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)


def check_vectorized_merge_matches_pairwise(data):
    """merge_many over a stacked axis == any pairwise merge order == the full
    softmax (associativity is what licenses the split-KV decode finalize)."""
    s, v, cols = data
    rows, total = s.shape
    d = v.shape[1]
    n = total // cols
    states = []
    for i in range(n):
        st_i = osm.init_state((rows,), d)
        st_i = osm.update(st_i, jnp.asarray(s[:, i * cols:(i + 1) * cols]),
                          jnp.asarray(v[i * cols:(i + 1) * cols]))
        states.append(st_i)
    stacked = osm.SoftmaxState(m=jnp.stack([x.m for x in states]),
                               l=jnp.stack([x.l for x in states]),
                               acc=jnp.stack([x.acc for x in states]))
    o_vec, lse_vec = osm.finalize(osm.merge_many(stacked, axis=0))
    pair = states[0]
    for st_i in states[1:]:
        pair = osm.merge(pair, st_i)
    o_pair, lse_pair = osm.finalize(pair)
    np.testing.assert_allclose(np.asarray(o_vec), np.asarray(o_pair),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(lse_vec), np.asarray(lse_pair),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(o_vec), _softmax_weighted(s, v),
                               atol=1e-4, rtol=1e-4)
    # an all-empty stack merges to the empty state, NaN-free
    empty = osm.init_state((n, rows), d)
    o_e, lse_e = osm.finalize(osm.merge_many(empty, axis=0))
    assert float(jnp.abs(o_e).max()) == 0.0
    assert not bool(jnp.isnan(lse_e).any())


def check_shift_invariance(shift, data):
    """softmax(s + c) == softmax(s): the max-subtraction must absorb shifts."""
    s, v, cols = data
    rows, total = s.shape
    d = v.shape[1]

    def run(sarr):
        state = osm.init_state((rows,), d)
        state = osm.update(state, jnp.asarray(sarr), jnp.asarray(v))
        return osm.finalize(state)[0]

    o1 = run(s)
    o2 = run(s + np.float32(shift))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


def check_dropout_rng_statistics(seed, b, h, rate):
    """Keep-rate ≈ 1-rate; mask depends only on coordinates (replayable)."""
    qp = jnp.arange(256, dtype=jnp.int32)[:, None]
    kp = jnp.arange(256, dtype=jnp.int32)[None, :]
    m1 = rng.dropout_keep_mask(rate, seed, b, h, qp, kp)
    m2 = rng.dropout_keep_mask(rate, seed, b, h, qp, kp)
    assert bool(jnp.all(m1 == m2))
    keep = float(jnp.mean(m1))
    assert abs(keep - (1.0 - rate)) < 0.02


def check_dropout_rng_decorrelated_across_heads(seed):
    qp = jnp.arange(128, dtype=jnp.int32)[:, None]
    kp = jnp.arange(128, dtype=jnp.int32)[None, :]
    m_h0 = rng.dropout_keep_mask(0.5, seed, 0, 0, qp, kp)
    m_h1 = rng.dropout_keep_mask(0.5, seed, 0, 1, qp, kp)
    agree = float(jnp.mean(m_h0 == m_h1))
    assert 0.4 < agree < 0.6  # independent masks agree ~half the time


# ---------------------------------------------------------------------------
# hypothesis variants (skipped without hypothesis)
# ---------------------------------------------------------------------------

@given(score_blocks())
def test_blocked_equals_full_softmax(data):
    check_blocked_equals_full_softmax(data)


@given(score_blocks())
def test_merge_is_order_invariant(data):
    check_merge_is_order_invariant(data)


@given(score_blocks())
def test_vectorized_merge_matches_pairwise(data):
    check_vectorized_merge_matches_pairwise(data)


@given(st.floats(-50, 50), score_blocks())
def test_shift_invariance(shift, data):
    check_shift_invariance(shift, data)


@given(st.integers(0, 2**31 - 1), st.integers(0, 63), st.integers(0, 63),
       st.floats(0.05, 0.95))
def test_dropout_rng_statistics(seed, b, h, rate):
    check_dropout_rng_statistics(seed, b, h, rate)


@given(st.integers(0, 2**31 - 1))
def test_dropout_rng_decorrelated_across_heads(seed):
    check_dropout_rng_decorrelated_across_heads(seed)


# ---------------------------------------------------------------------------
# deterministic fallback: always runs, so the invariants are asserted even
# in containers without hypothesis
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", DET_CASES, ids=[str(c) for c in DET_CASES])
def test_det_softmax_state_invariants(case):
    data = _case(*case)
    check_blocked_equals_full_softmax(data)
    check_merge_is_order_invariant(data)
    check_vectorized_merge_matches_pairwise(data)
    check_shift_invariance(17.5, data)
    check_shift_invariance(-3.25, data)


@pytest.mark.parametrize("seed,b,h,rate", [(0, 0, 0, 0.1), (7, 3, 5, 0.5),
                                           (123, 63, 63, 0.9)])
def test_det_dropout_rng(seed, b, h, rate):
    check_dropout_rng_statistics(seed, b, h, rate)
    check_dropout_rng_decorrelated_across_heads(seed)


def test_det_fully_masked_state_is_zero():
    """A state fed only NEG_INF scores finalizes to zeros, not NaN/averages —
    the invariant behind the kernels' fully-masked-row handling (packed pad)."""
    state = osm.init_state((4,), 8)
    s = jnp.full((4, 16), osm.NEG_INF)
    v = jnp.ones((16, 8))
    state = osm.update(state, s, v)
    o, lse = osm.finalize(state)
    assert float(jnp.abs(o).max()) == 0.0
    assert not bool(jnp.isnan(lse).any())
    # a later real block must fully recover (transient garbage is rescaled out)
    state = osm.update(state, jnp.zeros((4, 16)), v)
    o2, _ = osm.finalize(state)
    np.testing.assert_allclose(np.asarray(o2), 1.0, atol=1e-6)
