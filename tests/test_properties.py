"""Hypothesis property tests for the system's core invariants.

The online-softmax state algebra (core/online_softmax.py) is the single piece
of math every execution path shares — kernel, XLA fallback, distributed decode
merge. If its invariants hold, block decomposition is sound everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import online_softmax as osm
from repro.kernels import rng

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _softmax_weighted(s, v):
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return p @ v


@st.composite
def score_blocks(draw):
    rows = draw(st.integers(2, 8))
    cols = draw(st.integers(2, 16))
    n_blocks = draw(st.integers(1, 4))
    d = draw(st.integers(1, 8))
    seed = draw(st.integers(0, 2**31 - 1))
    r = np.random.RandomState(seed)
    scale = draw(st.floats(0.1, 30.0))  # exercise large-magnitude scores
    s = (r.randn(rows, n_blocks * cols) * scale).astype(np.float32)
    v = r.randn(n_blocks * cols, d).astype(np.float32)
    return s, v, cols


@given(score_blocks())
def test_blocked_equals_full_softmax(data):
    """Folding blocks sequentially == softmax over the concatenation (Eq. 3)."""
    s, v, cols = data
    rows, total = s.shape
    d = v.shape[1]
    state = osm.init_state((rows,), d)
    for i in range(total // cols):
        state = osm.update(state, jnp.asarray(s[:, i * cols:(i + 1) * cols]),
                           jnp.asarray(v[i * cols:(i + 1) * cols]))
    o, lse = osm.finalize(state)
    o_ref = _softmax_weighted(s, v)
    np.testing.assert_allclose(np.asarray(o), o_ref, atol=1e-4, rtol=1e-4)
    # lse is the true log-sum-exp
    lse_ref = np.log(np.exp(s - s.max(-1, keepdims=True)).sum(-1)) + s.max(-1)
    np.testing.assert_allclose(np.asarray(lse), lse_ref, atol=1e-4, rtol=1e-4)


@given(score_blocks())
def test_merge_is_order_invariant(data):
    """State merge is commutative+associative → kv blocks can be processed in
    any order (this is what licenses the distributed flash-decode merge)."""
    s, v, cols = data
    rows, total = s.shape
    d = v.shape[1]
    n = total // cols
    states = []
    for i in range(n):
        st_i = osm.init_state((rows,), d)
        st_i = osm.update(st_i, jnp.asarray(s[:, i * cols:(i + 1) * cols]),
                          jnp.asarray(v[i * cols:(i + 1) * cols]))
        states.append(st_i)
    fwd = states[0]
    for st_i in states[1:]:
        fwd = osm.merge(fwd, st_i)
    rev = states[-1]
    for st_i in reversed(states[:-1]):
        rev = osm.merge(rev, st_i)
    o1, l1 = osm.finalize(fwd)
    o2, l2 = osm.finalize(rev)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)


@given(st.floats(-50, 50), score_blocks())
def test_shift_invariance(shift, data):
    """softmax(s + c) == softmax(s): the max-subtraction must absorb shifts."""
    s, v, cols = data
    rows, total = s.shape
    d = v.shape[1]

    def run(sarr):
        state = osm.init_state((rows,), d)
        state = osm.update(state, jnp.asarray(sarr), jnp.asarray(v))
        return osm.finalize(state)[0]

    o1 = run(s)
    o2 = run(s + np.float32(shift))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


@given(st.integers(0, 2**31 - 1), st.integers(0, 63), st.integers(0, 63),
       st.floats(0.05, 0.95))
def test_dropout_rng_statistics(seed, b, h, rate):
    """Keep-rate ≈ 1-rate; mask depends only on coordinates (replayable)."""
    qp = jnp.arange(256, dtype=jnp.int32)[:, None]
    kp = jnp.arange(256, dtype=jnp.int32)[None, :]
    m1 = rng.dropout_keep_mask(rate, seed, b, h, qp, kp)
    m2 = rng.dropout_keep_mask(rate, seed, b, h, qp, kp)
    assert bool(jnp.all(m1 == m2))
    keep = float(jnp.mean(m1))
    assert abs(keep - (1.0 - rate)) < 0.02


@given(st.integers(0, 2**31 - 1))
def test_dropout_rng_decorrelated_across_heads(seed):
    qp = jnp.arange(128, dtype=jnp.int32)[:, None]
    kp = jnp.arange(128, dtype=jnp.int32)[None, :]
    m_h0 = rng.dropout_keep_mask(0.5, seed, 0, 0, qp, kp)
    m_h1 = rng.dropout_keep_mask(0.5, seed, 0, 1, qp, kp)
    agree = float(jnp.mean(m_h0 == m_h1))
    assert 0.4 < agree < 0.6  # independent masks agree ~half the time
