"""Flash-decode kernel vs. oracle, incl. ragged kv_len and sliding windows."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import max_err
from repro.kernels.ops import decode, decode_reference
from repro.core.attention import spark_decode

_BIG = pytest.mark.slow  # long-cache interpret sweeps: slow tier
CASES = [
    # b, hq, hkv, skv, d, window, block_kv
    pytest.param((2, 8, 8, 512, 64, None, 128), marks=_BIG),
    (2, 8, 2, 512, 64, None, 128),       # GQA: group packed into MXU rows
    pytest.param((1, 4, 1, 1024, 128, None, 512), marks=_BIG),  # MQA
    (2, 4, 2, 512, 64, 256, 128),        # sliding window (recurrentgemma-style)
    (1, 4, 4, 300, 64, None, 128),       # non-divisible cache length
    (1, 10, 1, 256, 256, None, 128),     # recurrentgemma head geometry
]


def _mk(key, b, hq, hkv, skv, d):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, hkv, skv, d))
    v = jax.random.normal(ks[2], (b, hkv, skv, d))
    return q, k, v


@pytest.mark.parametrize("case", CASES,
                         ids=[str(getattr(c, "values", (c,))[0])
                              for c in CASES])
def test_decode_matches_oracle(rng_key, case):
    b, hq, hkv, skv, d, window, block = case
    q, k, v = _mk(rng_key, b, hq, hkv, skv, d)
    o = decode(q, k, v, window=window, block_kv=block, interpret=True)
    o_ref = decode_reference(q, k, v, window=window)
    assert max_err(o, o_ref) < 2e-5


def test_decode_ragged_kv_len(rng_key):
    b, hq, hkv, skv, d = 3, 4, 2, 512, 64
    q, k, v = _mk(rng_key, b, hq, hkv, skv, d)
    kv_len = jnp.array([512, 130, 17], jnp.int32)
    o = decode(q, k, v, kv_len=kv_len, block_kv=128, interpret=True)
    o_ref = decode_reference(q, k, v, kv_len=np.array([512, 130, 17]))
    assert max_err(o, o_ref) < 2e-5


def test_decode_xla_path_matches_kernel(rng_key):
    """spark_decode impl='xla' (dry-run path) ≡ the Pallas kernel."""
    b, hq, hkv, skv, d = 2, 4, 2, 256, 64
    q, k, v = _mk(rng_key, b, hq, hkv, skv, d)
    kv_len = jnp.array([256, 100], jnp.int32)
    o_k = spark_decode(q, k, v, impl="pallas_interpret", kv_len=kv_len)
    o_x = spark_decode(q, k, v, impl="xla", kv_len=kv_len)
    assert max_err(o_k, o_x) < 2e-5


def test_decode_is_fwd_last_row(rng_key):
    """Decoding the final token ≡ the last row of a full forward pass."""
    b, hq, hkv, skv, d = 1, 4, 2, 256, 64
    q4, k, v = _mk(rng_key, b, hq, hkv, skv, d)
    from repro.kernels.ref import naive_mha
    # treat cache K/V as the sequence; the query is the (already appended) last
    q_full = jax.random.normal(jax.random.PRNGKey(9), (b, hq, skv, d))
    o_full = naive_mha(q_full, k, v, causal=True)
    o_dec = decode(q_full[:, :, -1, :], k, v, interpret=True)
    assert max_err(o_dec, o_full[:, :, -1, :]) < 2e-5
