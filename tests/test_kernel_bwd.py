"""MHA-Backward dual-pass kernels vs. jax.grad of the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_qkv, max_err
from repro.kernels.flash_fwd import flash_fwd
from repro.kernels.flash_bwd import flash_bwd
from repro.kernels.ops import mha, AttnConfig
from repro.kernels.ref import naive_mha

_BIG = pytest.mark.slow  # 256+-seq dual-pass interpret sweeps: slow tier
CASES = [
    # b, hq, hkv, sq, skv, d, causal, window, drop
    pytest.param((2, 2, 2, 256, 256, 64, False, None, 0.0), marks=_BIG),
    pytest.param((2, 4, 2, 256, 256, 64, True, None, 0.0),
                 marks=_BIG),                    # GQA group-sum of dK/dV
                                                 # (group-sum also default-
                                                 # covered by test_edge_cases)
    (1, 2, 1, 128, 384, 128, True, None, 0.0),   # suffix query
    pytest.param((1, 2, 2, 256, 256, 64, True, 64, 0.0),
                 marks=_BIG),                    # sliding window
    (1, 2, 2, 200, 200, 64, True, None, 0.0),    # padding
    (1, 2, 2, 128, 128, 64, False, None, 0.15),  # dropout replay in recompute
    (1, 2, 2, 128, 128, 80, True, None, 0.0),    # head_dim 80
]


def _ref_grads(q, k, v, do, causal, window, drop):
    def f(q, k, v):
        o = naive_mha(q, k, v, causal=causal, window=window,
                      dropout_rate=drop, dropout_seed=3)
        return (o * do).sum()
    return jax.grad(f, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("case", CASES,
                         ids=[str(getattr(c, "values", (c,))[0])
                              for c in CASES])
def test_bwd_matches_oracle_grads(rng_key, case):
    b, hq, hkv, sq, skv, d, causal, window, drop = case
    q, k, v, do = make_qkv(rng_key, b, hq, hkv, sq, skv, d)
    dq_r, dk_r, dv_r = _ref_grads(q, k, v, do, causal, window, drop)
    o, lse = flash_fwd(q, k, v, causal=causal, window=window,
                       dropout_rate=drop, dropout_seed=3,
                       block_q=64, block_kv=64, interpret=True)
    dq, dk, dv = flash_bwd(q, k, v, o, lse, do, causal=causal, window=window,
                           dropout_rate=drop, dropout_seed=3,
                           block_q=64, block_kv=64, interpret=True)
    assert max_err(dq, dq_r) < 5e-5
    assert max_err(dk, dk_r) < 5e-5
    assert max_err(dv, dv_r) < 5e-5
    assert dk.shape == k.shape and dv.shape == v.shape


def test_custom_vjp_under_jit(rng_key):
    """The paper's pybind11-into-PyTorch glue, JAX-style: grad-of-jit works."""
    q, k, v, do = make_qkv(rng_key, 2, 4, 2, 128, 128, 64)
    cfg = AttnConfig(causal=True, block_q=64, block_kv=64, interpret=True)

    @jax.jit
    def loss(q, k, v, seed):
        return (mha(q, k, v, seed=seed, config=cfg) * do).sum()

    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v, jnp.int32(0))
    dq_r, dk_r, dv_r = _ref_grads(q, k, v, do, True, None, 0.0)
    assert max_err(g[0], dq_r) < 5e-5
    assert max_err(g[1], dk_r) < 5e-5
    assert max_err(g[2], dv_r) < 5e-5


def test_bwd_bf16_acc(rng_key):
    """Paper: backward offered in FP16-ACC only ('does not require high
    precision'). bf16-ACC grads must stay within bf16 roundoff of the oracle."""
    q, k, v, do = make_qkv(rng_key, 1, 2, 2, 128, 128, 64, dtype=jnp.bfloat16)
    qf, kf, vf, dof = (x.astype(jnp.float32) for x in (q, k, v, do))
    dq_r, dk_r, dv_r = _ref_grads(qf, kf, vf, dof, True, None, 0.0)
    o, lse = flash_fwd(q, k, v, causal=True, interpret=True)
    dq, dk, dv = flash_bwd(q, k, v, o, lse, do, causal=True,
                           acc_dtype=jnp.bfloat16, interpret=True)
    assert max_err(dq, dq_r) < 0.35   # bf16 has ~3 decimal digits
    assert max_err(dk, dk_r) < 0.35
    assert max_err(dv, dv_r) < 0.35


def test_dropout_train_eval_consistency(rng_key):
    """Same seed → forward and backward see identical masks (paper §4.2.2)."""
    q, k, v, do = make_qkv(rng_key, 1, 2, 2, 128, 128, 64)
    cfg = AttnConfig(dropout_rate=0.3, block_q=64, block_kv=64, interpret=True)

    def loss(q, k, v):
        return (mha(q, k, v, seed=11, config=cfg) * do).sum()

    # finite-difference check on a single coordinate: only valid if bwd mask
    # matches fwd mask exactly
    g = jax.grad(loss)(q, k, v)
    eps = 1e-3
    e = jnp.zeros_like(q).at[0, 0, 0, 0].set(eps)
    fd = (loss(q + e, k, v) - loss(q - e, k, v)) / (2 * eps)
    assert abs(float(fd) - float(g[0, 0, 0, 0])) < 5e-2
