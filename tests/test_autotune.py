"""Split-KV launch-parameter autotuner (perf/autotune.py).

Contracts: a pure cost-model plan is always valid (no device, no sweep); the
model prefers splitting exactly where the ROADMAP says the machine idles
(long caches × small ``B·Hkv``) and leaves well-occupied shapes alone; the
persistent cache round-trips through JSON, is keyed by the full decode
geometry, and survives corrupt files; the sweep hook overrides the model;
and the serving engine actually bakes the planned split count into its
decode step.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.perf.autotune import (AutotuneCache, DecodeShape, LaunchPlan,
                                 candidate_plans, plan_decode, predict_time)


LONG_SMALL_BATCH = DecodeShape(batch=1, hkv=2, group=4, kv_len=500_000,
                               head_dim=128)


def _assert_valid(shape, plan):
    nk = -(-shape.kv_len // plan.block_kv)
    assert plan.num_splits >= 1
    assert plan.num_splits <= nk          # every split owns >= 1 KV block
    assert plan.block_kv >= 1
    if shape.page_size > 0:
        assert plan.block_kv == shape.page_size
    assert plan.time_s > 0 and np.isfinite(plan.time_s)


def test_pure_cost_model_plans_are_valid():
    shapes = [
        LONG_SMALL_BATCH,
        DecodeShape(batch=32, hkv=8, group=4, kv_len=2048, head_dim=128),
        DecodeShape(batch=2, hkv=2, group=4, kv_len=32768, head_dim=128,
                    page_size=16),
        DecodeShape(batch=1, hkv=1, group=8, kv_len=64, head_dim=64),
        DecodeShape(batch=1, hkv=1, group=1, kv_len=3, head_dim=64),
    ]
    for shape in shapes:
        plan = plan_decode(shape)          # no sweep, no cache, no device
        _assert_valid(shape, plan)
        assert plan.source == "model"


def test_cost_model_splits_where_occupancy_is_low():
    """long_500k at B·Hkv=2 must split; a saturated batch must not."""
    assert plan_decode(LONG_SMALL_BATCH).num_splits > 1
    busy = DecodeShape(batch=64, hkv=8, group=4, kv_len=2048, head_dim=128)
    assert plan_decode(busy).num_splits == 1
    tiny = DecodeShape(batch=1, hkv=1, group=8, kv_len=64, head_dim=64)
    assert plan_decode(tiny).num_splits == 1   # merge overhead dominates


def test_predict_time_monotonic_in_traffic():
    s1 = dataclasses.replace(LONG_SMALL_BATCH, kv_len=10_000)
    s2 = dataclasses.replace(LONG_SMALL_BATCH, kv_len=100_000)
    assert predict_time(s2, 1, 512) > predict_time(s1, 1, 512)


def test_candidates_respect_page_size():
    paged = DecodeShape(batch=2, hkv=2, group=2, kv_len=4096, head_dim=64,
                        page_size=32)
    assert {bk for _, bk in candidate_plans(paged)} == {32}
    contig = DecodeShape(batch=2, hkv=2, group=2, kv_len=4096, head_dim=64)
    assert all(bk <= 4096 for _, bk in candidate_plans(contig))


def test_cache_round_trips_and_is_shape_keyed(tmp_path):
    path = tmp_path / "autotune.json"
    cache = AutotuneCache(path)
    s1 = LONG_SMALL_BATCH
    s2 = dataclasses.replace(s1, batch=2)              # differs in one field
    p1 = plan_decode(s1, cache=cache)
    p2 = plan_decode(s2, cache=cache)
    cache.save()
    assert json.loads(path.read_text())                # valid JSON on disk
    reloaded = AutotuneCache(path)
    h1, h2 = reloaded.get(s1), reloaded.get(s2)
    assert h1 is not None and h2 is not None
    assert (h1.num_splits, h1.block_kv) == (p1.num_splits, p1.block_kv)
    assert (h2.num_splits, h2.block_kv) == (p2.num_splits, p2.block_kv)
    assert h1.source == "cache"
    # a hit short-circuits the model: plan_decode returns the cached record
    assert plan_decode(s1, cache=reloaded).source == "cache"
    # distinct geometries never collide
    assert s1.key() != s2.key()


def test_cache_env_override_and_corrupt_file(tmp_path, monkeypatch):
    env_path = tmp_path / "via_env.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(env_path))
    assert AutotuneCache.default_path() == env_path
    env_path.write_text("{not json")
    cache = AutotuneCache()                            # corrupt → empty, no raise
    assert cache.get(LONG_SMALL_BATCH) is None
    plan_decode(LONG_SMALL_BATCH, cache=cache)
    cache.save()
    assert json.loads(env_path.read_text())


def test_sweep_hook_overrides_model(tmp_path):
    """The measured time ranks the model's shortlist, not the model."""
    times = {}

    def sweep(ns, bk):
        # invert the model's preference: make bigger splits "measure" slower
        times[(ns, bk)] = float(ns)
        return times[(ns, bk)]
    plan = plan_decode(LONG_SMALL_BATCH, sweep=sweep)
    assert times, "sweep was never invoked"
    assert plan.source == "sweep"
    best = min(times, key=times.get)
    assert (plan.num_splits, plan.block_kv) == best    # measurement won
    assert plan.num_splits == min(ns for ns, _ in times)


def test_engine_autotune_wires_plan(tmp_path, monkeypatch):
    """ServingEngine(autotune=True) bakes the planned split count in and
    still serves; the plan lands in the persistent cache."""
    from repro import configs
    from repro.models import lm
    from repro.serving import PagedCacheConfig, ServingEngine

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    cfg = dataclasses.replace(configs.smoke_config("qwen3_14b"),
                              dtype=jnp.float32, remat=False)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_batch=2,
                            max_pages_per_seq=6)
    eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                        xla_chunk=16, autotune=True)
    shape = DecodeShape(batch=pcfg.max_batch, hkv=cfg.num_kv_heads,
                        group=cfg.num_heads // cfg.num_kv_heads,
                        kv_len=pcfg.max_pages_per_seq * pcfg.page_size,
                        head_dim=cfg.head_dim, page_size=pcfg.page_size,
                        dtype_bytes=jnp.dtype(cfg.dtype).itemsize)
    assert eng.num_splits == plan_decode(shape).num_splits
    assert AutotuneCache().get(shape) is not None       # persisted
    rs = np.random.RandomState(0)
    out, _ = eng.run([(rs.randint(0, cfg.vocab_size, size=8), 4)])
    assert len(out[0]) == 4
    # an explicit num_splits beats autotune
    eng2 = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                         xla_chunk=16, autotune=True, num_splits=2)
    assert eng2.num_splits == 2
