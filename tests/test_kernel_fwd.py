"""MHA-Forward Pallas kernel vs. the pure-jnp oracle (interpret mode).

Sweeps shapes × dtypes × masking modes × accumulate precisions, mirroring the
paper's §4.2.3 accuracy methodology (oracle = f32 unfused attention).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_qkv, max_err
from repro.kernels.flash_fwd import flash_fwd
from repro.kernels.ref import naive_mha, online_mha

_BIG = pytest.mark.slow  # 256+-seq interpret sweeps: slow tier
CASES = [
    # b, hq, hkv, sq, skv, d, causal, window, bq, bkv
    pytest.param((2, 4, 4, 256, 256, 64, False, None, 128, 128), marks=_BIG),
    (2, 4, 2, 256, 256, 64, True, None, 128, 128),
    (1, 8, 1, 128, 128, 128, True, None, 64, 64),      # MQA
    (1, 2, 1, 128, 384, 128, True, None, 64, 128),     # suffix query (chunked prefill)
    (1, 2, 2, 256, 256, 64, True, 64, 64, 64),         # sliding window
    pytest.param((1, 2, 2, 256, 256, 64, False, 128, 128, 128),
                 marks=_BIG),                          # window, non-causal
    (1, 2, 2, 200, 200, 64, True, None, 128, 128),     # pad: seq not divisible
    pytest.param((1, 2, 2, 192, 320, 80, False, None, 64, 64),
                 marks=_BIG),                          # head_dim 80 (hubert)
    (1, 1, 1, 64, 64, 256, True, None, 64, 64),        # head_dim 256 (recurrentgemma)
    (3, 2, 2, 96, 96, 64, True, None, 32, 32),         # odd batch, small blocks
]


def _ids(cases):
    return [str(getattr(c, "values", (c,))[0]) for c in cases]


@pytest.mark.parametrize("case", CASES, ids=_ids(CASES))
def test_fwd_matches_oracle(rng_key, case):
    b, hq, hkv, sq, skv, d, causal, window, bq, bkv = case
    q, k, v, _ = make_qkv(rng_key, b, hq, hkv, sq, skv, d)
    o, lse = flash_fwd(q, k, v, causal=causal, window=window,
                       block_q=bq, block_kv=bkv, interpret=True)
    o_ref, lse_ref = naive_mha(q, k, v, causal=causal, window=window,
                               return_residuals=True)
    assert o.shape == (b, hq, sq, d)
    assert max_err(o, o_ref) < 2e-5
    assert max_err(lse, lse_ref) < 2e-5


@pytest.mark.parametrize("case", CASES[:4], ids=_ids(CASES[:4]))
def test_online_xla_matches_oracle(rng_key, case):
    """The dry-run XLA path implements the identical algorithm."""
    b, hq, hkv, sq, skv, d, causal, window, bq, bkv = case
    q, k, v, _ = make_qkv(rng_key, b, hq, hkv, sq, skv, d)
    o = online_mha(q, k, v, causal=causal, window=window, chunk=64)
    o_ref = naive_mha(q, k, v, causal=causal, window=window)
    assert max_err(o, o_ref) < 2e-5


def test_bf16_acc_variant(rng_key):
    """Paper's FP16-ACC analogue: matmuls accumulate in bf16; softmax stays f32."""
    q, k, v, _ = make_qkv(rng_key, 2, 4, 4, 256, 256, 64, dtype=jnp.bfloat16)
    o16, _ = flash_fwd(q, k, v, acc_dtype=jnp.bfloat16, interpret=True)
    o32, _ = flash_fwd(q, k, v, acc_dtype=jnp.float32, interpret=True)
    o_ref = naive_mha(q.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32))
    # bf16-ACC is less accurate than f32-ACC but must stay within bf16 roundoff
    assert max_err(o16, o_ref) < 0.05
    assert max_err(o32, o_ref) <= max_err(o16, o_ref) + 1e-6


def test_dropout_matches_oracle_mask(rng_key):
    """In-kernel dropout regenerates exactly the oracle's coordinate-hash mask."""
    q, k, v, _ = make_qkv(rng_key, 1, 2, 2, 128, 128, 64)
    o, _ = flash_fwd(q, k, v, dropout_rate=0.1, dropout_seed=7,
                     block_q=64, block_kv=64, interpret=True)
    o_ref = naive_mha(q, k, v, dropout_rate=0.1, dropout_seed=7)
    assert max_err(o, o_ref) < 2e-5


def test_dropout_block_decomposition_invariance(rng_key):
    """Masks derive from global coordinates → block size must not change them."""
    q, k, v, _ = make_qkv(rng_key, 1, 2, 2, 256, 256, 64)
    o1, _ = flash_fwd(q, k, v, dropout_rate=0.2, dropout_seed=3,
                      block_q=64, block_kv=64, interpret=True)
    o2, _ = flash_fwd(q, k, v, dropout_rate=0.2, dropout_seed=3,
                      block_q=128, block_kv=32, interpret=True)
    assert max_err(o1, o2) < 1e-5


def test_fully_masked_rows_are_zero():
    """window=1 + suffix offset can fully mask rows; output must be 0, not NaN."""
    q = jnp.ones((1, 1, 64, 64))
    k = jnp.ones((1, 1, 64, 64))
    v = jnp.ones((1, 1, 64, 64))
    # non-causal with a window that excludes everything for early rows is not
    # constructible; instead use causal + tiny window and check no NaNs anywhere
    o, lse = flash_fwd(q, k, v, causal=True, window=1, interpret=True)
    assert not bool(jnp.isnan(o).any())
    assert not bool(jnp.isnan(lse).any())
