"""The public ops surface stays oracle-covered (sparklint: ops-test-coverage).

``kernels/ops.py`` is the repo's public attention API; every entrypoint must
be exercised by at least one test so kernel/fallback/oracle agreement cannot
silently rot. This module covers the two pure-XLA oracles the kernel tests
consume only indirectly: ``ops.mha_reference`` (the unfused baseline) and
``ops.mha_xla`` (the fused algorithm in plain XLA) must agree with each
other — forward and gradients — across causal/window/GQA/packed variants.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ops import AttnConfig


def _qkv(b=2, hq=4, hkv=2, sq=16, skv=16, d=8, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(k1, (b, hq, sq, d), jnp.float32),
            jax.random.normal(k2, (b, hkv, skv, d), jnp.float32),
            jax.random.normal(k3, (b, hkv, skv, d), jnp.float32))


@pytest.mark.parametrize("causal,window", [(False, None), (True, None),
                                           (True, 8)])
def test_mha_xla_matches_reference(causal, window):
    q, k, v = _qkv()
    cfg = AttnConfig(causal=causal, window=window)
    o_ref = ops.mha_reference(q, k, v, config=cfg)
    o_xla = ops.mha_xla(q, k, v, config=cfg, chunk=8)
    np.testing.assert_allclose(o_xla, o_ref, atol=2e-5, rtol=2e-5)


def test_mha_xla_grads_match_reference():
    q, k, v = _qkv(sq=8, skv=8)
    cfg = AttnConfig(causal=True)

    def loss(fn):
        return lambda q_, k_, v_: jnp.sum(fn(q_, k_, v_, config=cfg) ** 2)

    g_ref = jax.grad(loss(ops.mha_reference), argnums=(0, 1, 2))(q, k, v)
    g_xla = jax.grad(loss(ops.mha_xla), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_xla, g_ref):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)


def test_mha_xla_packed_segments_match_reference():
    q, k, v = _qkv(hq=2, hkv=2)
    # two segments + a padded tail (negative ids): packed-batch layout
    seg = jnp.asarray([[0] * 6 + [1] * 8 + [-1] * 2,
                       [0] * 10 + [1] * 4 + [-1] * 2], jnp.int32)
    cfg = AttnConfig(causal=True)
    o_ref = ops.mha_reference(q, k, v, segment_ids=seg, config=cfg)
    o_xla = ops.mha_xla(q, k, v, segment_ids=seg, config=cfg, chunk=8)
    np.testing.assert_allclose(o_xla, o_ref, atol=2e-5, rtol=2e-5)
    # padded rows emit exact zeros in both oracles
    assert not np.any(np.asarray(o_ref[:, :, -2:]))
    assert not np.any(np.asarray(o_xla[:, :, -2:]))
