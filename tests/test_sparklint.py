"""sparklint (tools/analysis) — per-rule fixture pairs + framework behavior.

Each rule gets a violating snippet and a clean one, written into a tmp tree
shaped like the repo (the rules scope themselves by repo-relative globs, so
the same rule code runs unchanged here and on the real tree). On top:
suppression handling (justified disables silence, unjustified disables are
themselves findings), JSON output schema, CLI exit codes — and the
acceptance gate: the real tree must lint clean.
"""

import json
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from tools.analysis import run  # noqa: E402
from tools.analysis.__main__ import main  # noqa: E402


def make_tree(tmp_path, files):
    for rel, content in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(content))
    return tmp_path


def rule_ids(tmp_path, files, rules=None):
    return [f.rule for f in run(make_tree(tmp_path, files), rules=rules)]


# ---------------------------------------------------------------- rule 1

FOLD_BAD = """
    import jax.numpy as jnp

    def kern(s, m_prev):
        m = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m[:, None])
        return p
"""

FOLD_CLEAN = """
    from repro.kernels.common import online_fold

    def kern(s, v, acc_ref, m_ref, l_ref):
        online_fold(s, v, acc_ref, m_ref, l_ref, acc_dtype="float32")
"""


def test_fold_rule_flags_inline_exp(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/kernels/newkern.py": FOLD_BAD},
                   rules=["no-inline-softmax-fold"])
    assert ids == ["no-inline-softmax-fold"]


def test_fold_rule_clean_when_routed(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/kernels/newkern.py": FOLD_CLEAN},
                   rules=["no-inline-softmax-fold"])
    assert ids == []


def test_fold_rule_exempts_canonical_homes(tmp_path):
    files = {
        "src/repro/kernels/common.py": """
            import jax.numpy as jnp

            def online_fold(s, v, acc_ref, m_ref, l_ref):
                p = jnp.exp(s - m_ref[:, 0][:, None])
                return p
        """,
        "src/repro/core/online_softmax.py": """
            import jax.numpy as jnp

            def update(state, s, v):
                return jnp.exp(s - state[0])
        """,
    }
    assert rule_ids(tmp_path, files, rules=["no-inline-softmax-fold"]) == []


# ---------------------------------------------------------------- rule 2

LAUNCH_BAD = """
    import jax
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def wrapper(kernel, interpret):
        return pl.pallas_call(
            kernel, grid=(1,),
            compiler_params=pltpu.CompilerParams(
                dimension_semantics=("parallel",)))
"""

LAUNCH_BARE = """
    from jax.experimental import pallas as pl

    def wrapper(kernel):
        return pl.pallas_call(kernel, grid=(1,))
"""

LAUNCH_CLEAN = """
    from jax.experimental import pallas as pl
    from repro.kernels.common import mosaic_kwargs

    def wrapper(kernel, interpret):
        return pl.pallas_call(kernel, grid=(1,),
                              **mosaic_kwargs(interpret, ("parallel",)))

    def wrapper2(kernel, interpret):
        kwargs = mosaic_kwargs(interpret, ("parallel",))
        return pl.pallas_call(kernel, grid=(1,), **kwargs)
"""


def test_launch_rule_flags_inline_params(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/kernels/k.py": LAUNCH_BAD},
                   rules=["mosaic-kwargs-launch"])
    # inline compiler_params AND missing helper: two findings on one call
    assert ids == ["mosaic-kwargs-launch"] * 2


def test_launch_rule_flags_bare_call(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/kernels/k.py": LAUNCH_BARE},
                   rules=["mosaic-kwargs-launch"])
    assert ids == ["mosaic-kwargs-launch"]


def test_launch_rule_clean_both_forms(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/kernels/k.py": LAUNCH_CLEAN},
                   rules=["mosaic-kwargs-launch"])
    assert ids == []


# ---------------------------------------------------------------- rule 3

ACC_BAD = """
    import jax.numpy as jnp
    from jax.experimental.pallas import tpu as pltpu

    SCRATCH = pltpu.VMEM((8, 128), jnp.bfloat16)

    def kern(acc_ref, pv, alpha):
        acc_ref[...] = (acc_ref[...] * alpha).astype(jnp.float16) + pv
"""

ACC_CLEAN = """
    import jax.numpy as jnp
    from jax.experimental.pallas import tpu as pltpu

    SCRATCH = pltpu.VMEM((8, 128), jnp.float32)

    def kern(acc_ref, pv, alpha):
        acc_ref[...] = acc_ref[...] * alpha + pv.astype(jnp.float32)
"""


def test_f32_rule_flags_downcasts(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/kernels/k.py": ACC_BAD},
                   rules=["f32-accumulators"])
    assert ids == ["f32-accumulators"] * 2      # bf16 scratch + f16 store


def test_f32_rule_clean(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/kernels/k.py": ACC_CLEAN},
                   rules=["f32-accumulators"])
    assert ids == []


# ---------------------------------------------------------------- rule 4

MASK_BAD = """
    import jax.numpy as jnp

    NEG = -1e9

    def mask(s, allowed):
        s = jnp.where(allowed, s, -jnp.inf)
        return jnp.where(allowed, s, float("-inf"))
"""

MASK_CLEAN = """
    import jax.numpy as jnp
    from repro.core.online_softmax import NEG_INF

    def mask(s, allowed):
        return jnp.where(allowed, s, NEG_INF)
"""


def test_mask_rule_flags_local_constants(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/models/m.py": MASK_BAD},
                   rules=["shared-mask-constant"])
    assert ids == ["shared-mask-constant"] * 3


def test_mask_rule_clean_and_definition_site_exempt(tmp_path):
    files = {
        "src/repro/models/m.py": MASK_CLEAN,
        "src/repro/core/online_softmax.py": "NEG_INF = -1e30\n",
    }
    assert rule_ids(tmp_path, files, rules=["shared-mask-constant"]) == []


# ---------------------------------------------------------------- rule 5

HOST_BAD = """
    import numpy as np
    import jax.numpy as jnp

    def schedule(queue):
        return jnp.asarray(queue)
"""

HOST_FROM_BAD = """
    from jax import numpy as jnp
"""

HOST_CLEAN = """
    import numpy as np

    def schedule(queue):
        return np.asarray(queue)
"""


def test_host_rule_flags_jax_imports(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/serving/scheduler.py": HOST_BAD,
                              "src/repro/serving/drafter.py": HOST_FROM_BAD},
                   rules=["host-layer-numpy-only"])
    assert ids == ["host-layer-numpy-only"] * 2


def test_host_rule_clean_and_engine_exempt(tmp_path):
    files = {"src/repro/serving/paged_cache.py": HOST_CLEAN,
             "src/repro/serving/engine.py": "import jax\n"}
    assert rule_ids(tmp_path, files, rules=["host-layer-numpy-only"]) == []


def test_host_rule_covers_state_cache(tmp_path):
    """The recurrent-state slot cache is host bookkeeping too."""
    ids = rule_ids(tmp_path, {"src/repro/serving/state_cache.py": HOST_BAD},
                   rules=["host-layer-numpy-only"])
    assert ids == ["host-layer-numpy-only"]


# ---------------------------------------------------------------- rule 6

DONATE_BAD = """
    import jax

    def make():
        def decode_fn(params, token, caches):
            return caches

        return jax.jit(decode_fn)
"""

DONATE_USE_BAD = """
    import jax

    def make():
        def decode_fn(params, caches):
            return caches

        step = jax.jit(decode_fn, donate_argnums=(1,))

        def drive(params, caches):
            out = step(params, caches)
            return out, caches
        return drive
"""

DONATE_CLEAN = """
    import jax

    def make():
        def decode_fn(params, token, caches):
            return caches

        step = jax.jit(decode_fn, donate_argnums=(2,))

        def drive(params, token, caches):
            caches = step(params, token, caches)
            return caches
        return drive
"""


def test_donate_rule_flags_undonated_pool(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/runtime/steps.py": DONATE_BAD},
                   rules=["donate-page-pool"])
    assert ids == ["donate-page-pool"]


def test_donate_rule_flags_read_after_donation(tmp_path):
    fs = run(make_tree(tmp_path,
                       {"src/repro/runtime/steps.py": DONATE_USE_BAD}),
             rules=["donate-page-pool"])
    assert [f.rule for f in fs] == ["donate-page-pool"]
    assert "read after being donated" in fs[0].message


def test_donate_rule_clean_rebind(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/runtime/steps.py": DONATE_CLEAN},
                   rules=["donate-page-pool"])
    assert ids == []


# ---------------------------------------------------------------- rule 7

FSDP_BAD = """
    from repro.configs import ArchConfig

    CONFIG = ArchConfig(name="x", sharding_profile="fsdp")
"""

FSDP_CLEAN = """
    from repro.configs import ArchConfig

    CONFIG = ArchConfig(name="x", sharding_profile="fsdp", fsdp=True)
    OTHER = ArchConfig(name="y", sharding_profile="tp_sp")
"""


def test_fsdp_rule_flags_annotation_alone(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/configs/x.py": FSDP_BAD},
                   rules=["fsdp-profile-gate"])
    assert ids == ["fsdp-profile-gate"]


def test_fsdp_rule_clean_with_flag(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/configs/x.py": FSDP_CLEAN},
                   rules=["fsdp-profile-gate"])
    assert ids == []


# ---------------------------------------------------------------- rule 8

OPS_FIXTURE = """
    def covered(q, k, v):
        return q

    def uncovered(q, k, v):
        return k

    def _private(q):
        return q
"""

TEST_FIXTURE = """
    from repro.kernels import ops

    def test_covered():
        assert ops.covered(1, 2, 3) == 1
"""


def test_ops_coverage_flags_untested_entrypoint(tmp_path):
    fs = run(make_tree(tmp_path, {"src/repro/kernels/ops.py": OPS_FIXTURE,
                                  "tests/test_ops.py": TEST_FIXTURE}),
             rules=["ops-test-coverage"])
    assert [f.rule for f in fs] == ["ops-test-coverage"]
    assert "uncovered" in fs[0].message


def test_ops_coverage_clean_when_referenced(tmp_path):
    files = {"src/repro/kernels/ops.py": OPS_FIXTURE,
             "tests/test_ops.py": TEST_FIXTURE
             + "\n    def test_more():\n        ops.uncovered(1, 2, 3)\n"}
    assert rule_ids(tmp_path, files, rules=["ops-test-coverage"]) == []


# ---------------------------------------------------------------- rule 9

ARCHS_FIXTURE = """
    ARCHS = [
        "alpha_1b", "beta_2b",
    ]
"""

ZOO_FIXTURE = """
    import pytest

    @pytest.mark.parametrize("arch", ["alpha_1b"])
    def test_engine_matches_oracle(arch):
        assert arch
"""


def test_zoo_coverage_flags_unserved_config(tmp_path):
    fs = run(make_tree(tmp_path,
                       {"src/repro/configs/__init__.py": ARCHS_FIXTURE,
                        "tests/test_config_zoo.py": ZOO_FIXTURE}),
             rules=["config-zoo-coverage"])
    assert [f.rule for f in fs] == ["config-zoo-coverage"]
    assert "beta_2b" in fs[0].message


def test_zoo_coverage_flags_missing_matrix(tmp_path):
    fs = run(make_tree(tmp_path,
                       {"src/repro/configs/__init__.py": ARCHS_FIXTURE}),
             rules=["config-zoo-coverage"])
    assert [f.rule for f in fs] == ["config-zoo-coverage"]
    assert "missing" in fs[0].message


def test_zoo_coverage_clean_when_every_config_named(tmp_path):
    files = {"src/repro/configs/__init__.py": ARCHS_FIXTURE,
             "tests/test_config_zoo.py": ZOO_FIXTURE.replace(
                 '["alpha_1b"]', '["alpha_1b", "beta_2b"]')}
    assert rule_ids(tmp_path, files, rules=["config-zoo-coverage"]) == []


# ---------------------------------------------------------------- rule 10

OUTCOME_BAD = """
    class Engine:
        def drop_row(self, seq):
            del self.scheduler.active[seq.slot]
            self.scheduler.tables.release(seq.slot)
            return True

        def sweep(self):
            return self.scheduler.evict_finished()
"""

OUTCOME_CLEAN = """
    class Engine:
        def _terminate(self, seq, outcome):
            del self.scheduler.active[seq.slot]
            self.scheduler.tables.release(seq.slot)
            self._record_outcome(seq.request.rid, outcome, seq.generated)

        def sweep(self):
            done = self.scheduler.evict_finished()
            for seq in done:
                self._record_outcome(seq.request.rid, Outcome.COMPLETED,
                                     seq.generated)
            return done
"""


def test_outcome_rule_flags_unrecorded_removal(tmp_path):
    fs = run(make_tree(tmp_path,
                       {"src/repro/serving/engine.py": OUTCOME_BAD}),
             rules=["engine-outcome-taxonomy"])
    assert [f.rule for f in fs] == ["engine-outcome-taxonomy"] * 2
    assert "drop_row" in fs[0].message and "sweep" in fs[1].message


def test_outcome_rule_clean_when_recorded(tmp_path):
    ids = rule_ids(tmp_path,
                   {"src/repro/serving/engine.py": OUTCOME_CLEAN},
                   rules=["engine-outcome-taxonomy"])
    assert ids == []


def test_outcome_rule_ignores_other_files(tmp_path):
    # scheduler.py's own release/evict calls are the engine's *mechanism*,
    # not its outcome bookkeeping — the rule scopes to engine.py only
    ids = rule_ids(tmp_path,
                   {"src/repro/serving/scheduler.py": OUTCOME_BAD},
                   rules=["engine-outcome-taxonomy"])
    assert ids == []


# ------------------------------------------------------- suppressions

SUPPRESSED = """
    import jax.numpy as jnp

    def kern(s, m):
        # sparklint: disable=no-inline-softmax-fold -- fixture: intentionally inline
        p = jnp.exp(s - m)
        q = jnp.exp(s - m)  # sparklint: disable=no-inline-softmax-fold -- same-line form
        return p + q
"""

UNJUSTIFIED = """
    import jax.numpy as jnp

    def kern(s, m):
        return jnp.exp(s - m)  # sparklint: disable=no-inline-softmax-fold
"""

WRONG_RULE = """
    import jax.numpy as jnp

    def kern(s, m):
        return jnp.exp(s - m)  # sparklint: disable=fsdp-profile-gate -- wrong id
"""


def test_suppression_silences_both_placements(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/kernels/k.py": SUPPRESSED},
                   rules=["no-inline-softmax-fold"])
    assert ids == []


def test_unjustified_suppression_is_a_finding(tmp_path):
    fs = run(make_tree(tmp_path, {"src/repro/kernels/k.py": UNJUSTIFIED}),
             rules=["no-inline-softmax-fold"])
    assert [f.rule for f in fs] == ["suppression-justification"]


def test_suppression_for_other_rule_does_not_apply(tmp_path):
    ids = rule_ids(tmp_path, {"src/repro/kernels/k.py": WRONG_RULE},
                   rules=["no-inline-softmax-fold"])
    assert ids == ["no-inline-softmax-fold"]


# ------------------------------------------------------- CLI / output

def test_json_output_schema(tmp_path, capsys):
    root = make_tree(tmp_path, {"src/repro/configs/x.py": FSDP_BAD})
    status = main(["--json", "--rule", "fsdp-profile-gate", str(root)])
    out = json.loads(capsys.readouterr().out)
    assert status == 1
    assert out["count"] == 1
    (f,) = out["findings"]
    assert set(f) == {"rule", "path", "line", "message"}
    assert f["rule"] == "fsdp-profile-gate"
    assert f["path"] == "src/repro/configs/x.py"
    assert isinstance(f["line"], int) and f["line"] > 0


def test_cli_exit_codes(tmp_path, capsys):
    clean = make_tree(tmp_path, {"src/repro/kernels/k.py": FOLD_CLEAN})
    assert main([str(clean)]) == 0
    assert main(["--rule", "no-such-rule", str(clean)]) == 2
    assert "ok (0 finding(s)" in capsys.readouterr().out


def test_unparsable_file_is_reported(tmp_path):
    fs = run(make_tree(tmp_path,
                       {"src/repro/kernels/k.py": "def broken(:\n"}),
             rules=["no-inline-softmax-fold"])
    assert fs and "unparsable" in fs[0].message


# ------------------------------------------------------- the real tree

def test_real_tree_is_clean():
    """The merged repo lints clean — the acceptance gate CI enforces."""
    assert run(REPO) == []
