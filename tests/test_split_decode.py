"""Split-KV flash decode: split ≡ unsplit on every path, plus edge cases.

The contracts behind ``num_splits`` (kernels/decode.py module docstring):

* partitioning the KV axis over parallel grid cells and merging the partial
  ``(acc, m, l)`` states in f32 changes nothing but the reduction order —
  split output ≡ unsplit output to f32-merge tolerance on the contiguous
  kernel, the paged kernel, the XLA fallback, GQA/MQA grouping, sliding
  windows and ragged ``kv_len`` (including fully-empty rows and empty splits);
* the partial-state variant composes: shard-local splits merge locally and
  the merged triple is identical, so the distributed cross-shard merge is
  oblivious to the split count;
* the serving engine with a split decode step generates token-identical
  output (the split choice is a launch parameter, not a semantic);
* the small-``skv`` alignment fix: caches shorter than one 8-row KV tile pad
  instead of producing sub-8-row tiles.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import max_err
from repro.core.attention import spark_decode, spark_paged_decode
from repro.kernels.ops import (decode, decode_reference, paged_decode,
                               paged_decode_partials, paged_decode_reference)

TOL = 2e-5  # f32 merge tolerance (same bound the unsplit kernel tests use)


def _mk(key, b, hq, hkv, skv, d):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, hkv, skv, d))
    v = jax.random.normal(ks[2], (b, hkv, skv, d))
    return q, k, v


def _mk_pool(key, b, hq, hkv, d, page_size, pages_per_row):
    num_pages = 1 + b * pages_per_row + 2
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k_pages = jax.random.normal(ks[1], (hkv, num_pages, page_size, d))
    v_pages = jax.random.normal(ks[2], (hkv, num_pages, page_size, d))
    perm = np.random.RandomState(7).permutation(num_pages - 1) + 1
    bt = jnp.asarray(perm[:b * pages_per_row].reshape(b, pages_per_row),
                     jnp.int32)
    return q, k_pages, v_pages, bt


# ---------------------------------------------------------------------------
# contiguous kernel
# ---------------------------------------------------------------------------

CONTIG_CASES = [
    # hq, hkv, skv, d, window, block_kv, num_splits
    (4, 4, 512, 64, None, 128, 2),       # MHA, even split
    (8, 2, 512, 64, None, 128, 4),       # GQA group in the MXU rows
    (4, 1, 384, 64, None, 64, 3),        # MQA, odd split of 6 blocks
    (4, 2, 512, 64, 200, 128, 4),        # sliding window across splits
    (4, 4, 300, 64, None, 128, 2),       # non-divisible cache length
    (4, 2, 512, 64, None, 128, 16),      # more splits than some rows need
]


@pytest.mark.parametrize("case", CONTIG_CASES, ids=[str(c) for c in CONTIG_CASES])
def test_contig_split_matches_unsplit(rng_key, case):
    hq, hkv, skv, d, window, block, ns = case
    b = 3
    q, k, v = _mk(rng_key, b, hq, hkv, skv, d)
    kv_len = jnp.array([skv, skv // 2 + 1, 5], jnp.int32)  # ragged incl. tiny
    o1 = decode(q, k, v, kv_len=kv_len, window=window, block_kv=block,
                interpret=True)
    o2 = decode(q, k, v, kv_len=kv_len, window=window, block_kv=block,
                num_splits=ns, interpret=True)
    assert max_err(o1, o2) < TOL
    o_ref = decode_reference(q, k, v, kv_len=np.asarray(kv_len),
                             window=window)
    assert max_err(o2, o_ref) < TOL


def test_contig_split_xla_matches_kernel(rng_key):
    """The XLA fallback's split path ≡ the split kernel ≡ unsplit."""
    b, hq, hkv, skv, d = 2, 8, 2, 320, 64
    q, k, v = _mk(rng_key, b, hq, hkv, skv, d)
    kv_len = jnp.array([skv, 100], jnp.int32)
    o_unsplit = spark_decode(q, k, v, impl="xla", kv_len=kv_len)
    for ns in (2, 3, 5):
        o_x = spark_decode(q, k, v, impl="xla", kv_len=kv_len, num_splits=ns)
        assert max_err(o_unsplit, o_x) < TOL, f"xla num_splits={ns}"
    o_k = spark_decode(q, k, v, impl="pallas_interpret", kv_len=kv_len,
                       block_kv=64, num_splits=4)
    assert max_err(o_unsplit, o_k) < TOL


def test_contig_split_empty_rows_and_splits(rng_key):
    """kv_len = 0 rows and splits with no valid blocks stay exact zeros /
    merge-inert (the NEG_INF-finite convention end to end)."""
    b, hq, hkv, skv, d = 3, 4, 2, 256, 64
    q, k, v = _mk(rng_key, b, hq, hkv, skv, d)
    kv_len = jnp.array([0, 17, 256], jnp.int32)
    for ns in (1, 4):
        o = decode(q, k, v, kv_len=kv_len, block_kv=64, num_splits=ns,
                   interpret=True)
        assert bool(jnp.isfinite(o).all())
        assert float(jnp.abs(o[0]).max()) == 0.0   # fully-masked row → zeros
    o_ref = decode_reference(q, k, v, kv_len=np.array([1, 17, 256]))
    o4 = decode(q, k, v, kv_len=kv_len, block_kv=64, num_splits=4,
                interpret=True)
    assert max_err(o4[1:], o_ref[1:]) < TOL


def test_small_skv_pads_to_tile(rng_key):
    """skv < 8 must pad to one 8-row KV tile, not emit a sub-8-row block."""
    b, hq, hkv, d = 2, 4, 2, 64
    for skv in (1, 3, 5, 7):
        q, k, v = _mk(jax.random.fold_in(rng_key, skv), b, hq, hkv, skv, d)
        o = decode(q, k, v, interpret=True)
        o_ref = decode_reference(q, k, v)
        assert max_err(o, o_ref) < TOL, f"skv={skv}"


def test_xla_split_of_window_short_rows(rng_key):
    """Windows spanning a split boundary on rows shorter than the window."""
    b, hq, hkv, skv, d = 2, 4, 2, 300, 64
    q, k, v = _mk(rng_key, b, hq, hkv, skv, d)
    kv_len = jnp.array([300, 40], jnp.int32)
    o1 = spark_decode(q, k, v, impl="xla", kv_len=kv_len, window=128)
    o2 = spark_decode(q, k, v, impl="xla", kv_len=kv_len, window=128,
                      num_splits=3)
    o3 = decode(q, k, v, kv_len=kv_len, window=128, block_kv=64,
                num_splits=3, interpret=True)
    assert max_err(o1, o2) < TOL
    assert max_err(o1, o3) < TOL


# ---------------------------------------------------------------------------
# paged kernel + partial-state composition
# ---------------------------------------------------------------------------

PAGED_CASES = [
    # hq, hkv, page_size, window, num_splits
    (4, 4, 32, None, 2),
    (8, 2, 32, None, 4),       # GQA
    (4, 2, 32, 60, 3),         # sliding window, odd split of 5 pages
    (4, 1, 64, None, 5),       # MQA, one page per split
]


@pytest.mark.parametrize("case", PAGED_CASES, ids=[str(c) for c in PAGED_CASES])
def test_paged_split_matches_unsplit(rng_key, case):
    hq, hkv, ps, window, ns = case
    b, d, t = 3, 64, 5
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([t * ps, ps + 3, 0], jnp.int32)
    o1 = paged_decode(q, kp, vp, bt, kv_len, window=window, interpret=True)
    o2 = paged_decode(q, kp, vp, bt, kv_len, window=window, num_splits=ns,
                      interpret=True)
    assert max_err(o1, o2) < TOL
    o_ref = paged_decode_reference(q, kp, vp, bt,
                                   np.maximum(np.asarray(kv_len), 1),
                                   window=window)
    assert max_err(o2[:2], o_ref[:2]) < TOL
    assert float(jnp.abs(o2[2]).max()) == 0.0


def test_paged_split_xla_matches_kernel(rng_key):
    b, hq, hkv, d, ps, t = 2, 4, 2, 64, 32, 4
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([t * ps, 40], jnp.int32)
    o_x1 = spark_paged_decode(q, kp, vp, bt, kv_len, impl="xla")
    o_x2 = spark_paged_decode(q, kp, vp, bt, kv_len, impl="xla", num_splits=3)
    o_k = spark_paged_decode(q, kp, vp, bt, kv_len, impl="pallas_interpret",
                             num_splits=3)
    assert max_err(o_x1, o_x2) < TOL
    assert max_err(o_x1, o_k) < TOL


def test_partials_split_composes_with_shard_merge(rng_key):
    """Shard-local splits merge locally: the partial triple is split-count
    independent, so the distributed cross-shard merge never sees the splits.
    Mirrors the hand-split two-shard merge test in test_paged.py."""
    from repro.core import online_softmax as osm
    b, hq, hkv, d, ps, t = 2, 4, 2, 64, 32, 4
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([t * ps, ps + 9], jnp.int32)
    # "shard" split: first two table entries vs last two, as validity masks
    v1 = jnp.asarray([[1, 1, 0, 0]] * b, jnp.int32)
    v2 = 1 - v1
    for ns in (1, 2, 4):
        parts = [paged_decode_partials(q, kp, vp, bt, kv_len, block_valid=bv,
                                       num_splits=ns, interpret=True)
                 for bv in (v1, v2)]
        states = [osm.SoftmaxState(m=m, l=l, acc=a) for a, m, l in parts]
        o, _ = osm.finalize(osm.merge(*states), out_dtype=q.dtype)
        o_full = paged_decode(q, kp, vp, bt, kv_len, interpret=True)
        assert max_err(o, o_full) < TOL, f"num_splits={ns}"


def test_partials_triple_is_split_invariant(rng_key):
    b, hq, hkv, d, ps, t = 2, 8, 2, 64, 32, 6
    q, kp, vp, bt = _mk_pool(rng_key, b, hq, hkv, d, ps, t)
    kv_len = jnp.array([t * ps, 3 * ps - 1], jnp.int32)
    a1, m1, l1 = paged_decode_partials(q, kp, vp, bt, kv_len, interpret=True)
    for ns in (2, 3, 6):
        a2, m2, l2 = paged_decode_partials(q, kp, vp, bt, kv_len,
                                           num_splits=ns, interpret=True)
        assert max_err(m1, m2) < TOL
        assert max_err(l1, l2) < 1e-4      # l is an un-normalised sum
        assert max_err(a1, a2) < 1e-4


# ---------------------------------------------------------------------------
# engine: the split count is a launch parameter, not a semantic
# ---------------------------------------------------------------------------

def _smoke_cfg():
    from repro import configs
    return dataclasses.replace(configs.smoke_config("qwen3_14b"),
                               dtype=jnp.float32, remat=False)


def test_engine_split_decode_is_token_identical():
    from repro.models import lm
    from repro.serving import PagedCacheConfig, ServingEngine

    cfg = _smoke_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    reqs = [(rs.randint(0, cfg.vocab_size, size=L).astype(np.int32), g)
            for L, g in [(12, 6), (7, 8), (9, 4)]]
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_batch=2,
                            max_pages_per_seq=6)
    outs = {}
    for ns in (1, 3):
        eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=24,
                            xla_chunk=16, num_splits=ns)
        assert eng.num_splits == ns
        outs[ns], _ = eng.run(list(reqs))
    for rid in outs[1]:
        assert np.array_equal(outs[1][rid], outs[3][rid]), f"request {rid}"
