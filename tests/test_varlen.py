"""Segment-packed (varlen) attention: all impls vs. a per-segment oracle.

The contract: ``spark_attention(..., segment_ids=...)`` on a packed batch is
numerically identical (≤1e-3 max-abs) to running each segment through the
naive reference independently — for forward AND gradients, on every impl.
Negative segment ids are padding: zero output, zero gradient.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_qkv, max_err
from repro.core.attention import spark_attention
from repro.kernels.flash_fwd import flash_fwd
from repro.kernels.flash_bwd import flash_bwd
from repro.kernels.ref import naive_mha

IMPLS = ("naive", "xla", "pallas_interpret")


def _segments(lengths, total, pad=False):
    """Non-decreasing segment ids from a list of lengths; -1 pads the tail."""
    ids = np.full((total,), -1 if pad else 0, np.int32)
    t = 0
    for sid, L in enumerate(lengths):
        ids[t:t + L] = sid
        t += L
    if not pad:
        assert t == total, "lengths must fill the row unless pad=True"
    return ids


def _per_segment_oracle(q, k, v, seg, *, causal):
    """Loop over segments, run the naive kernel on each slice independently.
    Assumes sq == skv (full self-attention rows). Padding (-1) rows → 0."""
    out = np.zeros(np.asarray(q).shape, np.float32)
    b = q.shape[0]
    for i in range(b):
        ids = np.asarray(seg[i])
        for sid in np.unique(ids[ids >= 0]):
            idx = np.where(ids == sid)[0]
            o = naive_mha(q[i:i + 1, :, idx], k[i:i + 1, :, idx],
                          v[i:i + 1, :, idx], causal=causal)
            out[i][:, idx] = np.asarray(o[0])
    return out


CASES = [
    # b, hq, hkv, s, d, lengths (per-row packing layout), causal, bq, bkv
    (2, 4, 4, 128, 32, [50, 40, 38], True, 32, 32),
    (2, 4, 2, 128, 32, [50, 40, 38], True, 32, 32),     # GQA
    (1, 2, 2, 128, 64, [128], False, 64, 64),           # single segment ≡ dense
    (1, 2, 2, 100, 32, [33, 40, 27], True, 32, 32),     # non-block-multiple seq
    (1, 8, 1, 96, 32, [8, 88], True, 32, 32),           # MQA, tiny first seg
]
# the real kernel bodies (pallas_interpret) run the distinctive cases; the
# cheaper oracle impls sample two apiece. CASES[0] (plain MHA) only runs on
# naive/xla — the group-1 pallas path is already exercised by every other
# pallas test in this file.
CASE_MATRIX = ([("pallas_interpret", c) for c in CASES[1:]] +
               [(i, c) for i in ("naive", "xla") for c in (CASES[0], CASES[3])])


@pytest.mark.parametrize("impl,case", CASE_MATRIX,
                         ids=[f"{i}-{c[:5]}{c[5]}" for i, c in CASE_MATRIX])
def test_varlen_fwd_matches_per_segment_oracle(rng_key, impl, case):
    b, hq, hkv, s, d, lengths, causal, bq, bkv = case
    q, k, v, _ = make_qkv(rng_key, b, hq, hkv, s, s, d)
    seg = jnp.asarray(np.tile(_segments(lengths, s), (b, 1)))
    o = spark_attention(q, k, v, impl=impl, causal=causal, segment_ids=seg,
                        block_q=bq, block_kv=bkv, xla_chunk=bkv)
    o_ref = _per_segment_oracle(q, k, v, seg, causal=causal)
    assert max_err(o, o_ref) < 1e-3


_GREF_CACHE = {}


@pytest.mark.parametrize("impl", IMPLS)
def test_varlen_grads_match_per_segment_oracle(rng_key, impl):
    b, hq, hkv, s, d = 1, 4, 2, 64, 32
    q, k, v, do = make_qkv(rng_key, b, hq, hkv, s, s, d)
    seg = jnp.asarray(_segments([28, 21, 15], s))[None, :]

    def loss(impl_):
        def f(q, k, v):
            o = spark_attention(q, k, v, impl=impl_, causal=True,
                                segment_ids=seg, block_q=32, block_kv=32,
                                xla_chunk=32)
            return (o * do).sum()
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    # gradient oracle: per-segment naive attention, summed. Inputs are a pure
    # function of the session rng_key, so share it across the impl params.
    def f_ref(q, k, v):
        tot = 0.0
        ids = np.asarray(seg[0])
        for sid in np.unique(ids):
            idx = np.where(ids == sid)[0]
            o = naive_mha(q[:, :, idx], k[:, :, idx], v[:, :, idx], causal=True)
            tot = tot + (o * do[:, :, idx]).sum()
        return tot

    if "g_ref" not in _GREF_CACHE:
        _GREF_CACHE["g_ref"] = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_ref = _GREF_CACHE["g_ref"]
    g = loss(impl)
    for a, r in zip(g, g_ref):
        assert max_err(a, r) < 1e-3


@pytest.mark.parametrize("impl", IMPLS)
def test_varlen_padding_rows_zero_fwd_and_grad(rng_key, impl):
    """Negative segment ids = padding: o == 0 (the l==0 finalize path in
    flash_fwd) and exactly zero gradient flows through padded tokens."""
    b, h, s, d = 1, 2, 96, 32
    q, k, v, do = make_qkv(rng_key, b, h, h, s, s, d)
    seg = jnp.asarray(_segments([40, 24], s, pad=True))[None, :]  # 32-token pad

    def f(q, k, v):
        o = spark_attention(q, k, v, impl=impl, causal=True, segment_ids=seg,
                            block_q=32, block_kv=32, xla_chunk=32)
        return (o * do).sum(), o

    (_, o), g = jax.value_and_grad(f, argnums=(0, 1, 2), has_aux=True)(q, k, v)
    o = np.asarray(o)
    assert not np.isnan(o).any()
    assert np.abs(o[:, :, 64:]).max() == 0.0          # padded q rows → zeros
    for gi in g:
        assert not bool(jnp.isnan(gi).any())
        assert float(jnp.abs(gi[:, :, 64:]).max()) == 0.0  # no grad into pad


def test_varlen_block_decomposition_invariance(rng_key):
    """Same packing, different block sizes → identical outputs (the segment
    block-skip must only skip provably-empty blocks)."""
    b, h, s, d = 1, 2, 128, 32
    q, k, v, _ = make_qkv(rng_key, b, h, h, s, s, d)
    seg = jnp.asarray(_segments([17, 60, 51], s))[None, :]
    o1, lse1 = flash_fwd(q, k, v, causal=True, segment_ids=seg,
                         block_q=32, block_kv=32, interpret=True)
    o2, lse2 = flash_fwd(q, k, v, causal=True, segment_ids=seg,
                         block_q=128, block_kv=64, interpret=True)
    assert max_err(o1, o2) < 1e-5
    assert max_err(lse1, lse2) < 1e-5


def test_varlen_suffix_query_chunked_prefill(rng_key):
    """sq < skv (chunked prefill): q takes the kv suffix's segment ids."""
    b, h, sq, skv, d = 1, 2, 64, 128, 32
    q, k, v, _ = make_qkv(rng_key, b, h, h, sq, skv, d)
    seg = jnp.asarray(_segments([80, 48], skv))[None, :]
    o, _ = flash_fwd(q, k, v, causal=True, segment_ids=seg,
                     block_q=32, block_kv=32, interpret=True)
    o_ref = naive_mha(q, k, v, causal=True, segment_ids=seg)
    assert max_err(o, o_ref) < 1e-3


def test_varlen_with_dropout_matches_across_impls(rng_key):
    """Dropout composes with segment masking identically on every impl."""
    b, h, s, d = 1, 2, 64, 32
    q, k, v, _ = make_qkv(rng_key, b, h, h, s, s, d)
    seg = jnp.asarray(_segments([30, 34], s))[None, :]
    outs = [spark_attention(q, k, v, impl=impl, causal=True, segment_ids=seg,
                            dropout_rate=0.2, seed=5, block_q=32, block_kv=32,
                            xla_chunk=32)
            for impl in IMPLS]
    assert max_err(outs[0], outs[1]) < 1e-5
    assert max_err(outs[0], outs[2]) < 1e-5


def test_varlen_packed_training_smoke(rng_key):
    """A packed batch trains end-to-end: finite loss, finite grads, and the
    loss ignores segment-boundary predictions."""
    from repro import configs
    from repro.data import DataConfig, make_batch
    from repro.models import lm
    from repro.models.layers import Ctx

    cfg = dataclasses.replace(configs.smoke_config("granite_3_2b"),
                              dtype=jnp.float32, remat=False, num_layers=2,
                              d_model=64, num_heads=2, num_kv_heads=2, d_ff=128)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=2,
                    pack=True, min_seg_len=8, max_seg_len=24)
    batch = {k2: jnp.asarray(v2) for k2, v2 in make_batch(dc, 0).items()}
    assert batch["segment_ids"].shape == (2, 64)
    # positions restart at each segment boundary
    seg0 = np.asarray(batch["segment_ids"][0])
    pos0 = np.asarray(batch["positions"][0])
    starts = np.where(np.diff(seg0) != 0)[0] + 1
    assert (pos0[starts] == 0).all() and pos0[0] == 0

    params, _ = lm.init_params(cfg, rng_key)
    ctx = Ctx(impl="xla", xla_chunk=32, block_q=32, block_kv=32)
    loss, _ = lm.loss_fn(cfg, params, batch, ctx)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: lm.loss_fn(cfg, p, batch, ctx)[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))


def test_varlen_packed_forward_equals_separate_docs(rng_key):
    """Packed forward of two documents ≡ two independent forwards (the whole
    plumbing: segment-masked attention + per-segment RoPE positions)."""
    from repro import configs
    from repro.models import lm
    from repro.models.layers import Ctx

    cfg = dataclasses.replace(configs.smoke_config("granite_3_2b"),
                              dtype=jnp.float32, remat=False, num_layers=2,
                              d_model=64, num_heads=2, num_kv_heads=2, d_ff=128)
    params, _ = lm.init_params(cfg, rng_key)
    ctx = Ctx(impl="xla", xla_chunk=16, block_q=16, block_kv=16)
    k1, k2 = jax.random.split(rng_key)
    t1 = jax.random.randint(k1, (1, 24), 0, cfg.vocab_size)
    t2 = jax.random.randint(k2, (1, 40), 0, cfg.vocab_size)
    packed = jnp.concatenate([t1, t2], axis=1)
    seg = jnp.concatenate([jnp.zeros((1, 24), jnp.int32),
                           jnp.ones((1, 40), jnp.int32)], axis=1)
    pos = jnp.concatenate([jnp.arange(24), jnp.arange(40)])[None, :]
    lp, _, _ = lm.forward(cfg, params, ctx, tokens=packed, segment_ids=seg,
                          positions=pos)
    l1, _, _ = lm.forward(cfg, params, ctx, tokens=t1)
    l2, _, _ = lm.forward(cfg, params, ctx, tokens=t2)
    assert max_err(lp[:, :24], l1) < 2e-4
    assert max_err(lp[:, 24:], l2) < 2e-4
