"""Paper Figure 10: MHA-Forward — fused vs unfused, sweeping sequence length.

Paper setting: hidden 2048, head_dim ∈ {64, 128}, heads = 2048/head_dim,
batch = 16384/seq, seq ∈ {512..16384}, causal ∈ {False, True}, dropout 0.1.
We run a CPU-scaled version of the same sweep (hidden 256, batch scaled) and
report: wall-µs for fused (online) vs naive, the derived HBM-byte ratio on the
paper's I/O model, and achieved GFLOP/s.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import mha_flops, mha_hbm_bytes, row, time_fn
from repro.kernels.ops import mha_reference, mha_xla, AttnConfig

HIDDEN = 256
TOKEN_BUDGET = 4096


def run(head_dim: int = 64, causal: bool = False, dropout: float = 0.1):
    heads = HIDDEN // head_dim
    results = []
    for seq in (512, 1024, 2048, 4096):
        batch = max(1, TOKEN_BUDGET // seq)
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (batch, heads, seq, head_dim))
        k = jax.random.normal(ks[1], (batch, heads, seq, head_dim))
        v = jax.random.normal(ks[2], (batch, heads, seq, head_dim))
        cfg = AttnConfig(causal=causal, dropout_rate=dropout)

        fused = jax.jit(functools.partial(mha_xla, config=cfg,
                                          chunk=min(512, seq)))
        naive = jax.jit(functools.partial(mha_reference, config=cfg))
        us_f = time_fn(fused, q, k, v)
        us_n = time_fn(naive, q, k, v)
        fl = mha_flops(batch, heads, seq, seq, head_dim, causal=causal)
        io_f = mha_hbm_bytes(batch, heads, heads, seq, seq, head_dim, fused=True)
        io_n = mha_hbm_bytes(batch, heads, heads, seq, seq, head_dim, fused=False)
        tag = f"hd{head_dim}_causal{int(causal)}_seq{seq}"
        row(f"mha_fwd_fused_{tag}", us_f,
            f"speedup={us_n/us_f:.2f}x;io_reduction={io_n/io_f:.1f}x;"
            f"gflops={fl/us_f/1e3:.1f}")
        row(f"mha_fwd_naive_{tag}", us_n, f"gflops={fl/us_n/1e3:.1f}")
        results.append((seq, us_f, us_n, io_n / io_f))
    return results


def main():
    for hd in (64, 128):
        for causal in (False, True):
            run(head_dim=hd, causal=causal)


if __name__ == "__main__":
    main()
