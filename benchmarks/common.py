"""Shared benchmark utilities.

The container is CPU-only, so wall-clock numbers are CPU-XLA timings of the
*algorithms* (fused online-softmax vs unfused naive) — they demonstrate the
paper's I/O argument qualitatively. The quantitative per-cell TPU numbers come
from the dry-run roofline artifacts (benchmarks/roofline_report.py).

Each benchmark prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time (µs) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def mha_flops(b, h, sq, skv, d, *, causal: bool) -> float:
    """2 matmuls (QKᵀ + PV), halved for causal — the paper's TFLOPs metric."""
    f = 4.0 * b * h * sq * skv * d
    return f / 2 if causal else f


def mha_hbm_bytes(b, h, hkv, sq, skv, d, *, fused: bool, dtype_bytes=2):
    """The paper's I/O accounting (§2.3 / §3.2): unfused reads/writes S and P
    (5 reads + 3 writes of N² and N·d tensors); fused reads Q,K,V once and
    writes O once (3 reads + 1 write)."""
    qkv = (b * h * sq * d + 2 * b * hkv * skv * d) * dtype_bytes
    o = b * h * sq * d * dtype_bytes
    if fused:
        return qkv + o                      # 3 reads + 1 write
    s_mat = b * h * sq * skv * dtype_bytes  # S and P round-trips
    return qkv + o + 2 * s_mat + 2 * s_mat  # write S, read S, write P, read P


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
