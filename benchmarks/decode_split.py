"""Split-KV flash-decode sweep: splits × batch × kv_len → BENCH_decode.json.

The perf trajectory of the split-KV work (kernels/decode.py): for every
(mode, batch, kv_len, num_splits) cell, time one jitted decode call, check it
against the unsplit result (f32-merge tolerance), and pair the measurement
with the perf/autotune.py cost-model prediction for the same launch — the
machine-readable JSON is the artifact CI and later PRs diff against.

On this CPU container the wall-clocks are XLA-CPU timings of the *algorithm*
(the split partial states + vectorized merge really execute); the TPU-side
winner is predicted by the cost model, which the autotuner tests pin.

  PYTHONPATH=src python benchmarks/decode_split.py                  # full sweep
  PYTHONPATH=src python benchmarks/decode_split.py --smoke          # CI guard
  PYTHONPATH=src python benchmarks/decode_split.py --impl pallas_interpret
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from common import row, time_fn
from repro.core.attention import spark_decode, spark_paged_decode
from repro.perf.autotune import DecodeShape, predict_time


def _contig_case(key, b, hq, hkv, kv_len, d):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, hkv, kv_len, d))
    v = jax.random.normal(ks[2], (b, hkv, kv_len, d))
    # ragged tail: last row half-full, exercising the kv_len skip under splits
    kv = np.full((b,), kv_len, np.int32)
    kv[-1] = max(1, kv_len // 2)
    return q, k, v, jnp.asarray(kv)


def _paged_case(key, b, hq, hkv, kv_len, d, page_size):
    t = -(-kv_len // page_size)
    num_pages = 1 + b * t
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kp = jax.random.normal(ks[1], (hkv, num_pages, page_size, d))
    vp = jax.random.normal(ks[2], (hkv, num_pages, page_size, d))
    perm = np.random.RandomState(0).permutation(num_pages - 1) + 1
    bt = jnp.asarray(perm[:b * t].reshape(b, t), jnp.int32)
    kv = np.full((b,), kv_len, np.int32)
    kv[-1] = max(1, kv_len // 2)
    return q, kp, vp, bt, jnp.asarray(kv)


def sweep(args):
    """Run the sweep; returns the list of per-cell result records."""
    b_list = [int(x) for x in args.batch.split(",")]
    kv_list = [int(x) for x in args.kv_len.split(",")]
    splits = [int(x) for x in args.splits.split(",")]
    hq, hkv, d = args.heads, args.kv_heads, args.head_dim
    key = jax.random.PRNGKey(0)
    results = []
    for mode in ("contig", "paged"):
        for b in b_list:
            for kv_len in kv_list:
                if mode == "contig":
                    q, k, v, kvl = _contig_case(key, b, hq, hkv, kv_len, d)

                    def call(ns):
                        return jax.jit(lambda q_, k_, v_, l_: spark_decode(
                            q_, k_, v_, impl=args.impl, kv_len=l_,
                            block_kv=args.block_kv, num_splits=ns)
                        ), (q, k, v, kvl)
                    shape = DecodeShape(batch=b, hkv=hkv, group=hq // hkv,
                                        kv_len=kv_len, head_dim=d,
                                        dtype_bytes=4)
                    block = args.block_kv
                else:
                    q, kp, vp, bt, kvl = _paged_case(key, b, hq, hkv, kv_len,
                                                     d, args.page_size)

                    def call(ns):
                        return jax.jit(lambda q_, kp_, vp_, bt_, l_:
                                       spark_paged_decode(
                                           q_, kp_, vp_, bt_, l_,
                                           impl=args.impl, num_splits=ns)
                        ), (q, kp, vp, bt, kvl)
                    shape = DecodeShape(batch=b, hkv=hkv, group=hq // hkv,
                                        kv_len=kv_len, head_dim=d,
                                        page_size=args.page_size,
                                        dtype_bytes=4)
                    block = args.page_size
                fn1, inputs = call(1)
                base = np.asarray(fn1(*inputs), np.float32)
                for ns in splits:
                    fn, inputs = call(ns)
                    out = np.asarray(fn(*inputs), np.float32)
                    err = float(np.abs(out - base).max())
                    us = time_fn(fn, *inputs, iters=args.iters,
                                 warmup=args.warmup)
                    pred = predict_time(shape, ns, block)
                    rec = {"mode": mode, "batch": b, "kv_len": kv_len,
                           "num_splits": ns, "block_kv": block, "us": us,
                           "predicted_tpu_us": pred * 1e6,
                           "max_err_vs_unsplit": err}
                    results.append(rec)
                    row(f"decode_{mode}_b{b}_kv{kv_len}_ns{ns}", us,
                        f"pred_tpu_us={pred*1e6:.2f} err={err:.2e}")
                    assert err < 2e-5, \
                        f"split decode diverged: {rec}"
    return results


def main(argv=None):
    """CLI entry point; writes the JSON artifact next to returning 0."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--splits", default="1,2,4,8")
    ap.add_argument("--batch", default="1,4")
    ap.add_argument("--kv-len", default="1024,8192")
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--block-kv", type=int, default=256,
                    help="contiguous-mode KV block")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas_interpret"])
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--out", default="BENCH_decode.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI guard: 2 batches × 1 kv_len × 2 splits")
    args = ap.parse_args(argv)
    if args.smoke:
        args.batch, args.kv_len, args.splits = "1,2", "256", "1,2"
        args.page_size, args.block_kv = 32, 64
        args.iters, args.warmup = 2, 1

    results = sweep(args)
    payload = {
        "bench": "decode_split",
        "impl": args.impl,
        "heads": args.heads, "kv_heads": args.kv_heads,
        "head_dim": args.head_dim,
        "smoke": bool(args.smoke),
        "results": results,
    }
    out = Path(args.out)
    out.write_text(json.dumps(payload, indent=1, sort_keys=True))
    json.loads(out.read_text())            # artifact must round-trip
    print(f"wrote {out} ({len(results)} cells)")


if __name__ == "__main__":
    main()
