"""Paper Figure 11: MHA-Backward — fused recompute backward vs naive autodiff.

The fused path stores only (O, LSE) and recomputes S/P in the backward (the
paper's memory-saving design); the naive path lets autodiff save the N²
attention matrix. We report wall-µs and the residual-memory ratio.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import mha_flops, row, time_fn
from repro.kernels.ops import mha_reference, mha_xla, AttnConfig

HIDDEN = 256
TOKEN_BUDGET = 2048


def run(head_dim: int = 64, causal: bool = False):
    heads = HIDDEN // head_dim
    for seq in (512, 1024, 2048):
        batch = max(1, TOKEN_BUDGET // seq)
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (batch, heads, seq, head_dim))
        k = jax.random.normal(ks[1], (batch, heads, seq, head_dim))
        v = jax.random.normal(ks[2], (batch, heads, seq, head_dim))
        do = jax.random.normal(ks[3], (batch, heads, seq, head_dim))
        cfg = AttnConfig(causal=causal)

        def loss_fused(q, k, v):
            return jnp.vdot(mha_xla(q, k, v, config=cfg,
                                    chunk=min(512, seq)), do)

        def loss_naive(q, k, v):
            return jnp.vdot(mha_reference(q, k, v, config=cfg), do)

        gf = jax.jit(jax.grad(loss_fused, argnums=(0, 1, 2)))
        gn = jax.jit(jax.grad(loss_naive, argnums=(0, 1, 2)))
        us_f = time_fn(gf, q, k, v)
        us_n = time_fn(gn, q, k, v)
        # residual memory: naive saves P [B,H,S,S]; fused saves lse [B,H,S]
        res_naive = batch * heads * seq * seq * 4
        res_fused = batch * heads * seq * 4 * 2
        fl = 2.5 * mha_flops(batch, heads, seq, seq, head_dim, causal=causal)
        tag = f"hd{head_dim}_causal{int(causal)}_seq{seq}"
        row(f"mha_bwd_fused_{tag}", us_f,
            f"speedup={us_n/us_f:.2f}x;residual_mem_reduction="
            f"{res_naive/res_fused:.0f}x;gflops={fl/us_f/1e3:.1f}")
        row(f"mha_bwd_naive_{tag}", us_n, f"gflops={fl/us_n/1e3:.1f}")


def main():
    for hd in (64, 128):
        run(head_dim=hd, causal=False)
    run(head_dim=64, causal=True)


if __name__ == "__main__":
    main()
