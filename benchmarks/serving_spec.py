"""Speculative decoding on the serving engine: plain vs verify-k → BENCH_spec.json.

Decode is latency-bound: each step re-reads the whole weight/KV working set
to emit ONE token per row.  Speculative decoding drafts ``k`` candidate
tokens per row with the prompt-lookup drafter (serving/drafter.py, no second
model) and scores all ``k + 1`` positions in one verify call, emitting every
greedily-accepted draft plus the model's own token at the first mismatch —
so the per-step HBM traffic amortizes over up to ``k + 1`` tokens while the
output stays BIT-IDENTICAL to plain greedy decode (asserted on every run).

Sections, each a row + a JSON record:
* ``plain``      — the baseline engine on the trace (k = 0).
* ``spec_k{K}``  — the speculative engine at each swept draft width, on a
  trace whose prompts tile short motifs (the n-gram drafter needs
  recurrences to match; uniform-random prompts rarely draft at all).
  Reports the measured acceptance rate, verify steps vs. plain decode
  steps, tokens per verify step, and wall ms/token.
* ``oracle_k{K}``— the same engine with a perfect-foresight drafter (drafts
  read from the plain run's own output), pinning the upper bound: 1.0
  acceptance, steps collapsed by ~(k+1)x.  The gap between ``spec`` and
  ``oracle`` is drafter quality, not verify overhead.

The container is CPU-only, so wall numbers time the XLA algorithms; the
step-count and acceptance columns are timing-independent and hold anywhere.

    PYTHONPATH=src python benchmarks/serving_spec.py            # full sweep
    PYTHONPATH=src python benchmarks/serving_spec.py --smoke    # CI guard
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from common import row


class OracleDrafter:
    """Perfect-foresight drafter: proposes the continuation of whichever
    reference stream (prompt + the plain run's generation) the row's history
    is a prefix of — the acceptance-rate upper bound for greedy verification."""

    def __init__(self, k, streams):
        self.k = k
        self.streams = [np.asarray(s, np.int32) for s in streams]

    def propose(self, history, max_tokens=-1):
        limit = self.k if max_tokens < 0 else min(self.k, max_tokens)
        h = np.asarray(history, np.int32)
        n = int(h.shape[0])
        if limit < 1:
            return np.zeros(0, np.int32)
        for s in self.streams:
            if s.shape[0] >= n and np.array_equal(s[:n], h):
                return s[n:n + limit].copy()
        return np.zeros(0, np.int32)


def make_trace(rs, vocab, n_requests, prompt_len, gen):
    """Ragged motif-tiled requests: repetition the n-gram drafter can hit."""
    reqs = []
    for _ in range(n_requests):
        plen = int(rs.randint(max(4, prompt_len // 2), prompt_len + 1))
        g = int(rs.randint(max(2, gen // 2), gen + 1))
        motif = rs.randint(0, vocab, size=int(rs.randint(3, 6)))
        reqs.append((np.tile(motif, -(-plen // len(motif)))[:plen]
                     .astype(np.int32), g))
    return reqs


def run_engine(cfg, pcfg, params, reqs, prefill_len, k, drafter=None):
    """One engine pass; returns (outputs, stats) with the pool drained."""
    from repro.serving import ServingEngine

    eng = ServingEngine(cfg, pcfg, params, impl="xla", xla_chunk=16,
                        prefill_len=prefill_len, speculate_k=k or None)
    if drafter is not None:
        eng.drafter = drafter
    out, stats = eng.run(list(reqs))
    return out, stats


def record(name, stats, out, base_steps=None):
    """One benchmark row + JSON record from an engine's stats dict."""
    n_tok = stats["generated_tokens"]
    ms_tok = stats["wall_s"] * 1e3 / max(n_tok, 1)
    rec = {
        "mode": name,
        "decode_steps": stats["decode_steps"],
        "generated_tokens": n_tok,
        "drafted_tokens": stats["drafted_tokens"],
        "accepted_tokens": stats["accepted_tokens"],
        "acceptance_rate": stats["acceptance_rate"],
        "tokens_per_step": n_tok / max(stats["decode_steps"], 1),
        "ms_per_token": ms_tok,
        "wall_s": stats["wall_s"],
        "preemptions": stats["preemptions"],
    }
    if base_steps is not None:
        rec["step_ratio_vs_plain"] = stats["decode_steps"] / max(base_steps, 1)
    row(f"serving_spec/{name}", stats["wall_s"] * 1e6,
        f"ms_per_tok={ms_tok:.2f};steps={stats['decode_steps']:.0f};"
        f"tok_per_step={rec['tokens_per_step']:.2f};"
        f"accept={stats['acceptance_rate']:.2f};"
        f"drafted={stats['drafted_tokens']:.0f}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ks", default="2,4,8",
                    help="draft widths to sweep (comma-separated)")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_spec.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI guard: one k, small trace, identity + "
                         "drafting-engaged asserted")
    args = ap.parse_args(argv)
    if args.smoke:
        args.ks, args.requests = "4", 4
        args.prompt_len, args.gen = 12, 8

    from repro import configs
    from repro.models import lm
    from repro.serving import PagedCacheConfig

    cfg = dataclasses.replace(configs.smoke_config("qwen3_14b"),
                              dtype=jnp.float32, remat=False)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    rs = np.random.RandomState(args.seed)
    reqs = make_trace(rs, cfg.vocab_size, args.requests, args.prompt_len,
                      args.gen)
    budget = args.prompt_len + args.gen
    pages = -(-budget // args.page_size) + 1
    pcfg = PagedCacheConfig(
        page_size=args.page_size, max_batch=4, max_pages_per_seq=pages,
        num_pages=1 + 4 * pages)
    ks = [int(k) for k in args.ks.split(",")]

    out_p, st_p = run_engine(cfg, pcfg, params, reqs, budget, 0)
    results = [record("plain", st_p, out_p)]
    streams = [np.concatenate([reqs[rid][0], out_p[rid]])
               for rid in sorted(out_p)]

    for k in ks:
        for label, drafter in ((f"spec_k{k}", None),
                               (f"oracle_k{k}", OracleDrafter(k, streams))):
            out_s, st_s = run_engine(cfg, pcfg, params, reqs, budget, k,
                                     drafter=drafter)
            assert set(out_s) == set(out_p)
            for rid in out_p:
                assert np.array_equal(out_s[rid], out_p[rid]), \
                    f"{label} diverged from plain greedy on request {rid}"
            results.append(record(label, st_s, out_s,
                                  base_steps=st_p["decode_steps"]))

    oracle = [r for r in results if r["mode"].startswith("oracle")]
    spec = [r for r in results if r["mode"].startswith("spec")]
    assert all(r["acceptance_rate"] == 1.0 for r in oracle), \
        "oracle drafts must all be accepted — verify/acceptance bug"
    assert all(r["decode_steps"] <= st_p["decode_steps"] for r in spec), \
        "a verify step emits at least one token; steps cannot exceed plain"
    if args.smoke:
        # the CI guard: drafting must actually engage, not just not crash
        assert all(r["drafted_tokens"] > 0 for r in spec), \
            "motif trace produced no drafts — drafter regression"
        assert all(r["step_ratio_vs_plain"] < 0.5 for r in oracle), \
            "oracle acceptance failed to collapse the step count"
        print("smoke ok: bit-identical to plain greedy, "
              f"ngram accept={spec[0]['acceptance_rate']:.2f}, "
              f"oracle tok/step={oracle[0]['tokens_per_step']:.2f} "
              f"vs plain 1.0")

    payload = {
        "bench": "serving_spec",
        "arch": "qwen3_14b(smoke)",
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "gen": args.gen,
        "page_size": args.page_size,
        "smoke": bool(args.smoke),
        "results": results,
    }
    out = Path(args.out)
    out.write_text(json.dumps(payload, indent=1, sort_keys=True))
    json.loads(out.read_text())            # artifact must round-trip
    print(f"wrote {out} ({len(results)} cells)")


if __name__ == "__main__":
    main()
