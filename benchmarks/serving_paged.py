"""Paged vs. contiguous KV-cache decode under ragged request lengths.

A serving batch is ragged: every sequence is at a different point of its
generation.  A contiguous cache must reserve ``B × max_len`` KV slots however
short the live sequences are; the paged cache reserves only the pages the
sequences actually own.  Both run the same flash-decode dataflow — the paged
kernel adds a scalar-prefetched block-table indirection in the K/V index maps
— so the comparison isolates (a) the step-time cost of the gather and (b) the
cache-memory utilization win.

Sections:
* ``step`` — one decode step over B ragged sequences, contiguous vs. paged:
  µs/step, decode throughput (tok/s), reserved KV bytes and utilization
  (live tokens / reserved capacity) for each layout.
* ``sharded step`` — the same paged decode with the pool page-sharded over a
  ("model",) mesh of all visible devices (per-shard local attention +
  online-softmax partial merge, distributed/paged.py): µs/step and the
  per-shard pool bytes. Run with fake devices to see real sharding, e.g.
  XLA_FLAGS=--xla_force_host_platform_device_count=2; on one device the
  mesh is (1,) and the numbers isolate the shard_map/merge overhead.
* ``engine`` (--engine) — the full continuous-batching engine on a smoke
  model, run twice on the same request trace: **eager** admission (full
  prompt+generation page budget reserved up front) vs. **lazy** (prompt-only
  reservation, one-page decode growth, youngest-row preemption + re-prefill
  when the pool runs dry).  Reports end-to-end tok/s and the
  reserved-vs-live-token utilization of each policy — lazy is strictly
  higher on any trace with generation (reserved pages track live tokens),
  at the price of occasional preemptions under pressure.
* ``prefix`` (--prefix, and the whole of --smoke) — the engine on a trace
  where every request opens with one common system prompt, run with prefix
  caching off vs. on (``share_prefix=True``): matched page-aligned prompt
  blocks alias the already-prefilled physical pages, so the shared prefix is
  prefilled **once** and every later request skips it.  Reports prefill
  tokens run vs. skipped, physical pages allocated per request, and the
  copy-on-write count — and asserts the generations are bit-identical to
  the unshared run, which is the whole point of content-addressed sharing.

The container is CPU-only: wall-clock numbers time the XLA algorithms (pass
--impl pallas_interpret to run the actual kernels, slow); the byte accounting
is layout math and holds on any backend.

    PYTHONPATH=src python benchmarks/serving_paged.py [--engine] [--prefix]
    PYTHONPATH=src python benchmarks/serving_paged.py --smoke    # CI guard
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from common import row, time_fn
from repro.core.attention import spark_decode, spark_paged_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas_interpret"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--min-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--shards", type=int, default=0,
                    help="pool shards for the sharded-step section "
                         "(default: all visible devices)")
    ap.add_argument("--engine", action="store_true",
                    help="also run the continuous-batching engine end to end")
    ap.add_argument("--prefix", action="store_true",
                    help="also run the shared-prefix engine comparison "
                         "(prefix caching off vs. on)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI guard: only the shared-prefix engine "
                         "comparison, small trace, identity asserted")
    args = ap.parse_args()

    if args.smoke:
        prefix_bench(np.random.RandomState(0), smoke=True)
        return

    rs = np.random.RandomState(0)
    b, hq, hkv, d, ps = (args.batch, args.heads, args.kv_heads, args.head_dim,
                         args.page_size)
    max_pages = -(-args.max_len // ps)
    kv_len = rs.randint(args.min_len, args.max_len + 1, size=b).astype(np.int32)
    dtype_bytes = 4  # f32 on CPU; the ratios are dtype-independent

    # ---- contiguous: every row reserves max_len slots ----
    q = jnp.asarray(rs.randn(b, hq, d), jnp.float32)
    kc = jnp.asarray(rs.randn(b, hkv, args.max_len, d), jnp.float32)
    vc = jnp.asarray(rs.randn(b, hkv, args.max_len, d), jnp.float32)
    kvl = jnp.asarray(kv_len)
    impl_c = "pallas_interpret" if args.impl == "pallas_interpret" else "xla"
    contig = jax.jit(lambda q_, k_, v_, l_: spark_decode(
        q_, k_, v_, impl=impl_c, kv_len=l_, block_kv=ps))
    us_c = time_fn(contig, q, kc, vc, kvl)
    bytes_c = 2 * b * hkv * args.max_len * d * dtype_bytes
    util_c = float(kv_len.sum()) / (b * args.max_len)

    # ---- paged: rows own only the pages that cover their tokens ----
    pages_per_row = -(-kv_len // ps)
    num_pages = 1 + int(pages_per_row.sum())        # + trash page 0
    k_pool, v_pool, tables = build_pool(rs, kc, vc, kv_len, num_pages,
                                        max_pages, ps, n_shards=1)
    kp, vp = jnp.asarray(k_pool), jnp.asarray(v_pool)
    bt = jnp.asarray(tables)
    paged = jax.jit(lambda q_, k_, v_, bt_, l_: spark_paged_decode(
        q_, k_, v_, bt_, l_, impl=args.impl))
    us_p = time_fn(paged, q, kp, vp, bt, kvl)
    bytes_p = 2 * hkv * num_pages * ps * d * dtype_bytes
    util_p = float(kv_len.sum()) / ((num_pages - 1) * ps)

    err = float(jnp.abs(paged(q, kp, vp, bt, kvl)
                        - contig(q, kc, vc, kvl)).max())
    print(f"# B={b} ragged kv_len {kv_len.min()}..{kv_len.max()} "
          f"(sum {kv_len.sum()}), max_len={args.max_len}, page_size={ps}, "
          f"impl={args.impl}; paged==contiguous max_err={err:.2e}")
    row("serving_paged/contiguous_step", us_c,
        f"tok_s={b / (us_c * 1e-6):.0f};kv_bytes={bytes_c};util={util_c:.2f}")
    row("serving_paged/paged_step", us_p,
        f"tok_s={b / (us_p * 1e-6):.0f};kv_bytes={bytes_p};util={util_p:.2f}")
    row("serving_paged/kv_bytes_ratio", 0.0,
        f"contiguous/paged={bytes_c / bytes_p:.2f}x")

    sharded_step_bench(args, rs, q, kc, vc, kv_len, contig)

    if args.engine:
        engine_bench(rs)
    if args.prefix:
        prefix_bench(rs)


def build_pool(rs, kc, vc, kv_len, num_pages, max_pages, ps, n_shards):
    """Scatter contiguous KV contents into a shuffled page pool.

    The per-shard trash pages (global s·num_pages/n_shards; just page 0 when
    n_shards == 1) are left unassigned. Returns (k_pool, v_pool, tables).
    """
    b, hkv, _, d = kc.shape
    per = num_pages // n_shards
    usable = [p for p in range(num_pages) if p % per != 0]
    perm = rs.permutation(len(usable))
    pages_per_row = -(-kv_len // ps)
    tables = np.zeros((b, max_pages), np.int32)
    k_pool = np.zeros((hkv, num_pages, ps, d), np.float32)
    v_pool = np.zeros((hkv, num_pages, ps, d), np.float32)
    nxt = 0
    for i in range(b):
        for t in range(int(pages_per_row[i])):
            pg = usable[int(perm[nxt])]; nxt += 1
            tables[i, t] = pg
            k_pool[:, pg] = np.asarray(kc[i, :, t * ps:(t + 1) * ps])
            v_pool[:, pg] = np.asarray(vc[i, :, t * ps:(t + 1) * ps])
    return k_pool, v_pool, tables


def sharded_step_bench(args, rs, q, kc, vc, kv_len, contig):
    """Paged decode with the pool page-sharded over all visible devices."""
    from repro.distributed.paged import paged_decode_sharded, pool_sharding
    from repro.launch.mesh import make_mesh

    n_shards = args.shards or len(jax.devices())
    mesh = make_mesh((n_shards,), ("model",))
    b, hkv, d, ps = args.batch, args.kv_heads, args.head_dim, args.page_size
    max_pages = -(-args.max_len // ps)
    pages_per_row = -(-kv_len // ps)
    # page-aligned pool: one trash page per shard (local page 0), padded so
    # the shard split divides evenly
    num_pages = n_shards + int(pages_per_row.sum())
    num_pages = -(-num_pages // n_shards) * n_shards
    per = num_pages // n_shards
    k_pool, v_pool, tables = build_pool(rs, kc, vc, kv_len, num_pages,
                                        max_pages, ps, n_shards=n_shards)
    kp = jax.device_put(jnp.asarray(k_pool), pool_sharding(mesh))
    vp = jax.device_put(jnp.asarray(v_pool), pool_sharding(mesh))
    bt, kvl = jnp.asarray(tables), jnp.asarray(kv_len)
    sharded = jax.jit(lambda q_, k_, v_, bt_, l_: paged_decode_sharded(
        q_, k_, v_, bt_, l_, mesh=mesh, impl=args.impl))
    us_s = time_fn(sharded, q, kp, vp, bt, kvl)
    err = float(jnp.abs(sharded(q, kp, vp, bt, kvl)
                        - contig(q, kc, vc, kvl)).max())
    bytes_per_shard = 2 * hkv * per * ps * d * 4
    row("serving_paged/sharded_step", us_s,
        f"tok_s={b / (us_s * 1e-6):.0f};shards={n_shards};"
        f"kv_bytes_per_shard={bytes_per_shard};merge_err={err:.1e}")


def engine_bench(rs):
    """End-to-end continuous batching: eager vs lazy on the same trace."""
    import dataclasses

    from repro import configs
    from repro.models import lm
    from repro.serving import PagedCacheConfig, ServingEngine

    cfg = dataclasses.replace(configs.smoke_config("qwen3_14b"),
                              dtype=jnp.float32, remat=False)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    pcfg = PagedCacheConfig(page_size=8, num_pages=33, max_batch=4,
                            max_pages_per_seq=8)
    reqs = [(rs.randint(0, cfg.vocab_size, size=int(rs.randint(8, 48))),
             int(rs.randint(4, 16))) for _ in range(12)]
    outs = {}
    for mode, lazy in (("eager", False), ("lazy", True)):
        eng = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=64,
                            xla_chunk=16, lazy=lazy)
        out, stats = eng.run(list(reqs))
        outs[mode] = (out, stats)
        row(f"serving_paged/engine_{mode}", stats["wall_s"] * 1e6,
            f"tok_s={stats['tokens_per_s']:.1f};requests={len(out)};"
            f"util={stats['mean_utilization']:.2f};"
            f"preemptions={stats['preemptions']:.0f};"
            f"pages_grown={stats['pages_grown']:.0f}")
    (out_e, st_e), (out_l, st_l) = outs["eager"], outs["lazy"]
    same = all(np.array_equal(out_e[r], out_l[r]) for r in out_e)
    row("serving_paged/engine_util_gain", 0.0,
        f"lazy/eager={st_l['mean_utilization'] / st_e['mean_utilization']:.2f}x;"
        f"token_identical={same}")


def prefix_bench(rs, smoke: bool = False):
    """Shared-system-prompt trace: prefix caching off vs. on.

    Every request is ``system prefix + per-request suffix`` — the agent /
    chat-serving shape where one long instruction block fronts every prompt.
    With sharing on, the first wave prefills the prefix once and registers
    its pages; every later request aliases them at admission, so its prefill
    shrinks to the suffix and its page footprint to the unshared tail.
    Asserts the generations are bit-identical between the two runs (smoke
    mode additionally asserts that reuse actually engaged: tokens skipped,
    pages per request down).
    """
    import dataclasses

    from repro import configs
    from repro.models import lm
    from repro.serving import PagedCacheConfig, ServingEngine

    cfg = dataclasses.replace(configs.smoke_config("qwen3_14b"),
                              dtype=jnp.float32, remat=False)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    if smoke:
        pcfg = PagedCacheConfig(page_size=8, num_pages=25, max_batch=2,
                                max_pages_per_seq=6)
        n_requests, prefix_len, prefill_len = 6, 24, 48
    else:
        pcfg = PagedCacheConfig(page_size=16, num_pages=65, max_batch=4,
                                max_pages_per_seq=12)
        n_requests, prefix_len, prefill_len = 16, 96, 192
    prefix = rs.randint(0, cfg.vocab_size, size=prefix_len).astype(np.int32)
    reqs = [(np.concatenate([prefix, rs.randint(
        0, cfg.vocab_size,
        size=int(rs.randint(4, 9))).astype(np.int32)]),
        int(rs.randint(4, 9))) for _ in range(n_requests)]

    outs = {}
    for mode, share in (("off", False), ("on", True)):
        eng = ServingEngine(cfg, pcfg, params, impl="xla",
                            prefill_len=prefill_len, xla_chunk=16,
                            share_prefix=share)
        out, stats = eng.run(list(reqs))
        outs[mode] = (out, stats)
        row(f"serving_paged/prefix_{mode}", stats["wall_s"] * 1e6,
            f"tok_s={stats['tokens_per_s']:.1f};"
            f"prefill_tokens={stats['prefill_tokens']:.0f};"
            f"skipped={stats['prefill_tokens_skipped']:.0f};"
            f"pages_per_req={stats['pages_allocated'] / len(out):.2f};"
            f"cow={stats['cow_copies']:.0f}")
    (out_off, st_off), (out_on, st_on) = outs["off"], outs["on"]
    same = all(np.array_equal(out_off[r], out_on[r]) for r in out_off)
    assert same, "prefix sharing changed a generation — COW/index bug"
    total_prompt = sum(len(t) for t, _ in reqs)
    row("serving_paged/prefix_reuse", 0.0,
        f"skipped_fraction={st_on['prefill_tokens_skipped'] / total_prompt:.2f};"
        f"pages_ratio={st_on['pages_allocated'] / st_off['pages_allocated']:.2f};"
        f"token_identical={same}")
    if smoke:
        # the CI guard: sharing must actually engage, not just not crash
        assert st_on["prefill_tokens_skipped"] >= \
            (n_requests - pcfg.max_batch) * (prefix_len - pcfg.page_size), \
            "prefix reuse below the aligned-prefix floor"
        assert st_on["prefill_tokens"] < st_off["prefill_tokens"]
        assert st_on["pages_allocated"] < st_off["pages_allocated"]
        print("smoke ok: shared prefixes skipped "
              f"{st_on['prefill_tokens_skipped']:.0f} prefill tokens, "
              f"pages/request {st_on['pages_allocated'] / len(out_on):.2f} "
              f"vs {st_off['pages_allocated'] / len(out_off):.2f} unshared, "
              "generations bit-identical")


if __name__ == "__main__":
    main()
