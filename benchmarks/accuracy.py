"""Paper §4.2.3: numerical accuracy of the fused kernels vs a f32 oracle.

Mirrors the paper's table: FP32-ACC and FP16-ACC (here bf16-ACC) relative /
absolute error of MHA-Forward, and bf16-ACC error of MHA-Backward, plus the
baseline's own bf16 error for context (the paper's PyTorch_FP16 row).
Kernels run in interpret mode — the same arithmetic the TPU kernel performs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.kernels.flash_fwd import flash_fwd
from repro.kernels.flash_bwd import flash_bwd
from repro.kernels.ref import naive_mha


def rel_abs_err(x, ref):
    x = np.asarray(x, np.float64)
    ref = np.asarray(ref, np.float64)
    abs_err = np.abs(x - ref)
    rel = abs_err / (np.abs(ref) + 1e-9)
    return float(np.mean(rel)) * 100, float(np.mean(abs_err)) * 100


def main():
    b, h, s, d = 2, 8, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    qf = jax.random.normal(ks[0], (b, h, s, d))
    kf = jax.random.normal(ks[1], (b, h, s, d))
    vf = jax.random.normal(ks[2], (b, h, s, d))
    do = jax.random.normal(ks[3], (b, h, s, d))
    o_ref, lse_ref = naive_mha(qf, kf, vf, causal=True, return_residuals=True)

    q16, k16, v16 = (x.astype(jnp.bfloat16) for x in (qf, kf, vf))

    # FP32-ACC forward (bf16 inputs, f32 matmul accumulation)
    o32, _ = flash_fwd(q16, k16, v16, causal=True, acc_dtype=jnp.float32,
                       interpret=True)
    r, a = rel_abs_err(o32, o_ref)
    row("accuracy_fwd_f32acc", 0, f"rel_err={r:.4f}%;abs_err={a:.4f}%")

    # BF16-ACC forward (paper's FP16-ACC)
    o16, _ = flash_fwd(q16, k16, v16, causal=True, acc_dtype=jnp.bfloat16,
                       interpret=True)
    r, a = rel_abs_err(o16, o_ref)
    row("accuracy_fwd_bf16acc", 0, f"rel_err={r:.4f}%;abs_err={a:.4f}%")

    # baseline low-precision unfused (paper's PyTorch_FP16 row)
    o_base = naive_mha(q16, k16, v16, causal=True, acc_dtype=jnp.bfloat16)
    r, a = rel_abs_err(o_base, o_ref)
    row("accuracy_fwd_naive_bf16", 0, f"rel_err={r:.4f}%;abs_err={a:.4f}%")

    # backward, bf16-ACC (paper backward is FP16-ACC only)
    def loss(q, k, v):
        return jnp.vdot(naive_mha(q, k, v, causal=True), do)
    dq_r, dk_r, dv_r = jax.grad(loss, argnums=(0, 1, 2))(qf, kf, vf)
    ob, lseb = flash_fwd(q16, k16, v16, causal=True, interpret=True)
    dq, dk, dv = flash_bwd(q16, k16, v16, ob, lseb, do.astype(jnp.bfloat16),
                           causal=True, acc_dtype=jnp.bfloat16, interpret=True)
    r, a = rel_abs_err(dq, dq_r)
    row("accuracy_bwd_bf16acc_dq", 0, f"rel_err={r:.4f}%;abs_err={a:.4f}%")
    r, a = rel_abs_err(dv, dv_r)
    row("accuracy_bwd_bf16acc_dv", 0, f"rel_err={r:.4f}%;abs_err={a:.4f}%")


if __name__ == "__main__":
    main()
