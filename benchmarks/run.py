"""Benchmark driver: one section per paper table/figure + the roofline report.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (accuracy, end_to_end, io_counts, mha_backward,
                            mha_forward, roofline_report)
    sections = [
        ("Fig.10 MHA-Forward (fused vs unfused)", mha_forward.main),
        ("Fig.11 MHA-Backward (recompute vs autodiff)", mha_backward.main),
        ("S4.2.3 Accuracy (bf16-ACC / f32-ACC vs f32 oracle)", accuracy.main),
        ("S2.3 HBM I/O counts (5R+3W vs 3R+1W)", io_counts.main),
        ("Fig.12 End-to-End encoder layer", end_to_end.main),
        ("Roofline report (dry-run artifacts)", roofline_report.main),
    ]
    failures = 0
    for title, fn in sections:
        print(f"\n# === {title} ===")
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
