"""Paper §2.3/§3.2: HBM I/O accounting — 5 reads + 3 writes (unfused) vs
3 reads + 1 write (fused), verified against the lowered HLO.

We count actual O(N²)-sized HBM round-trips in the compiled modules: the naive
implementation materialises S and P as real buffers; the fused (chunked
online) implementation must have NO N²-sized temp at all.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import mha_hbm_bytes, row
from repro.kernels.ops import mha_reference, mha_xla, AttnConfig


def main():
    b, h, s, d = 2, 4, 1024, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d)) for kk in ks)
    cfg = AttnConfig(causal=False)

    fused = jax.jit(functools.partial(mha_xla, config=cfg, chunk=256))
    naive = jax.jit(functools.partial(mha_reference, config=cfg))

    mem_f = fused.lower(q, k, v).compile().memory_analysis()
    mem_n = naive.lower(q, k, v).compile().memory_analysis()
    n2_bytes = b * h * s * s * 4
    row("io_fused_temp_bytes", 0,
        f"temp={mem_f.temp_size_in_bytes};n2_buffer={n2_bytes};"
        f"has_n2_temp={mem_f.temp_size_in_bytes >= n2_bytes}")
    row("io_naive_temp_bytes", 0,
        f"temp={mem_n.temp_size_in_bytes};n2_buffer={n2_bytes};"
        f"has_n2_temp={mem_n.temp_size_in_bytes >= n2_bytes}")
    io_f = mha_hbm_bytes(b, h, h, s, s, d, fused=True)
    io_n = mha_hbm_bytes(b, h, h, s, s, d, fused=False)
    row("io_model_reduction", 0,
        f"fused_bytes={io_f};naive_bytes={io_n};reduction={io_n/io_f:.1f}x")
    assert mem_f.temp_size_in_bytes < n2_bytes, \
        "fused path must not materialise the N^2 attention matrix"
    assert mem_n.temp_size_in_bytes >= n2_bytes


if __name__ == "__main__":
    main()
