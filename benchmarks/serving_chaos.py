"""Chaos harness for the serving engine: faults vs. invariants → BENCH_chaos.json.

Resilience claims are only as good as the harness that attacks them.  This
benchmark replays one ragged request trace through the engine under seeded
:class:`~repro.serving.faults.FaultPlan`s — pool exhaustion, preemption
storms, freed-page/state poisoning, NaN logits, mid-flight cancellations,
and a crash-at-step-N with snapshot/restore — and asserts the resilience
contract on every run:

* **typed termination** — every submitted rid ends in exactly one outcome
  (``COMPLETED | CANCELLED | TIMEOUT | SHED | FAILED``); no hangs (the run
  returning at all is the no-livelock check — the watchdog converts any
  wedged state into a ``FAILED`` outcome).
* **conservation** — after the pool drains, ``free + cached == usable``
  with nothing allocated, and every recurrent-state row is free.
* **isolation** — rows a fault did not touch produce tokens BIT-IDENTICAL
  to the fault-free baseline (greedy decode is schedule-invariant per row,
  so scheduling faults must not leak across rows).
* **replay** — the same FaultPlan seed reproduces the same outcomes and
  the same tokens, byte for byte.
* **recovery** — crash-at-step-N + snapshot/restore on a fresh engine
  resumes token-identically to the baseline.

The container is CPU-only; every asserted column here is timing-independent.

    PYTHONPATH=src python benchmarks/serving_chaos.py            # full sweep
    PYTHONPATH=src python benchmarks/serving_chaos.py --smoke    # CI guard
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from common import row


def make_trace(rs, vocab, n_requests, prompt_len, gen):
    """Ragged random requests (no motifs needed — no drafter here)."""
    reqs = []
    for _ in range(n_requests):
        plen = int(rs.randint(max(2, prompt_len // 2), prompt_len + 1))
        g = int(rs.randint(max(2, gen // 2), gen + 1))
        reqs.append((rs.randint(0, vocab, size=plen).astype(np.int32), g))
    return reqs


def build_engine(cfg, pcfg, params, prefill_len, plan=None):
    from repro.serving import ServingEngine
    return ServingEngine(cfg, pcfg, params, impl="xla", xla_chunk=16,
                         prefill_len=prefill_len, lazy=True,
                         fault_plan=plan)


def check_drained(eng):
    """Pool/state conservation after the queue drains — no fault may leak
    a page or a state row."""
    alloc = eng.scheduler.tables.allocator
    assert alloc.num_allocated == 0, \
        f"{alloc.num_allocated} pages still allocated after drain"
    assert alloc.num_free + alloc.num_cached == eng.pcfg.usable_pages, \
        "page conservation violated"
    st = eng.scheduler.tables.state
    assert st.num_occupied == 0 and st.num_free == st.capacity, \
        "state-row conservation violated"


def outcome_map(eng):
    return {rid: res.outcome.value for rid, res in eng.results.items()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", default="1,2,3,4",
                    help="FaultPlan seeds to sweep (comma-separated)")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI guard: two plans + the crash/restore "
                         "scenario, all invariants asserted")
    args = ap.parse_args(argv)
    if args.smoke:
        args.seeds, args.requests = "1,2", 6
        args.prompt_len, args.gen = 12, 8

    from repro import configs
    from repro.models import lm
    from repro.serving import (FaultPlan, InjectedCrash, Outcome,
                               PagedCacheConfig, untyped_rids)

    cfg = dataclasses.replace(configs.smoke_config("qwen3_14b"),
                              dtype=jnp.float32, remat=False)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    rs = np.random.RandomState(args.seed)
    reqs = make_trace(rs, cfg.vocab_size, args.requests, args.prompt_len,
                      args.gen)
    budget = args.prompt_len + args.gen
    pages = -(-budget // args.page_size) + 1
    pcfg = PagedCacheConfig(
        page_size=args.page_size, max_batch=4, max_pages_per_seq=pages,
        num_pages=1 + 3 * pages)   # tight: faults bite, requests still fit

    # fault-free baseline: the isolation reference
    eng0 = build_engine(cfg, pcfg, params, budget)
    out0, st0 = eng0.run(list(reqs))
    check_drained(eng0)
    assert len(out0) == len(reqs), "baseline must complete every request"
    results = [{"mode": "baseline", "outcomes": st0["outcomes"],
                "decode_steps": st0["decode_steps"],
                "generated_tokens": st0["generated_tokens"],
                "wall_s": st0["wall_s"]}]
    row("serving_chaos/baseline", st0["wall_s"] * 1e6,
        f"steps={st0['decode_steps']:.0f};"
        f"tokens={st0['generated_tokens']:.0f}")

    for seed in [int(s) for s in args.seeds.split(",")]:
        runs = []
        for rep in range(2):            # replay determinism: run each twice
            plan = FaultPlan(seed=seed, horizon=32)
            eng = build_engine(cfg, pcfg, params, budget, plan=plan)
            out, st = eng.run(list(reqs))
            check_drained(eng)
            assert untyped_rids(range(len(reqs)), eng.results) == [], \
                f"seed {seed}: requests terminated without a typed outcome"
            for rid, toks in out.items():   # isolation vs. fault-free run
                assert np.array_equal(toks, out0[rid]), \
                    f"seed {seed}: completed rid {rid} diverged from baseline"
            runs.append((outcome_map(eng), out, st))
        assert runs[0][0] == runs[1][0], \
            f"seed {seed}: outcomes differ across replays"
        assert set(runs[0][1]) == set(runs[1][1]) and all(
            np.array_equal(runs[0][1][r], runs[1][1][r])
            for r in runs[0][1]), f"seed {seed}: tokens differ across replays"
        omap, out, st = runs[0]
        results.append({"mode": f"chaos_seed{seed}",
                        "outcomes": st["outcomes"],
                        "decode_steps": st["decode_steps"],
                        "generated_tokens": st["generated_tokens"],
                        "watchdog_fires": st["watchdog_fires"],
                        "preemptions": st["preemptions"],
                        "wall_s": st["wall_s"]})
        row(f"serving_chaos/seed{seed}", st["wall_s"] * 1e6,
            ";".join(f"{k}={v}" for k, v in st["outcomes"].items() if v))

    # crash-at-step-N + snapshot/restore: token-identical recovery
    crash_at = 3
    plan = FaultPlan(seed=0, events=(), crash_step=crash_at)
    eng = build_engine(cfg, pcfg, params, budget, plan=plan)
    try:
        eng.run(list(reqs))
        raise AssertionError("injected crash did not fire")
    except InjectedCrash:
        snap = eng.snapshot()
    eng2 = build_engine(cfg, pcfg, params, budget)
    eng2.restore(snap)
    out2, st2 = eng2.run()
    check_drained(eng2)
    assert set(out2) == set(out0), "restore lost or invented requests"
    for rid in out0:
        assert np.array_equal(out2[rid], out0[rid]), \
            f"crash/restore diverged from baseline on rid {rid}"
    results.append({"mode": f"crash_restore@{crash_at}",
                    "outcomes": st2["outcomes"],
                    "decode_steps": st2["decode_steps"],
                    "generated_tokens": st2["generated_tokens"],
                    "wall_s": st2["wall_s"]})
    row("serving_chaos/crash_restore", st2["wall_s"] * 1e6,
        f"crash_at={crash_at};resumed_tokens={st2['generated_tokens']:.0f}")

    if args.smoke:
        chaos = [r for r in results if r["mode"].startswith("chaos")]
        assert any(sum(r["outcomes"].values())
                   - r["outcomes"][Outcome.COMPLETED.value] > 0
                   for r in chaos), \
            "no chaos run perturbed a single request — plans too tame for " \
            "a CI guard"
        print("smoke ok: typed outcomes + conservation + isolation + "
              "replay + crash/restore identity all hold")

    payload = {
        "bench": "serving_chaos",
        "arch": "qwen3_14b(smoke)",
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "gen": args.gen,
        "page_size": args.page_size,
        "smoke": bool(args.smoke),
        "results": results,
    }
    out_path = Path(args.out)
    out_path.write_text(json.dumps(payload, indent=1, sort_keys=True))
    json.loads(out_path.read_text())       # artifact must round-trip
    print(f"wrote {out_path} ({len(results)} cells)")


if __name__ == "__main__":
    main()
