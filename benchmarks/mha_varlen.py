"""Segment-packed (varlen) fused attention vs. padded-naive baseline.

Production traffic is ragged: many short documents per batch. The two ways to
feed them to attention are

* **padded-naive**: one row per document, each padded to the longest document,
  unfused attention (the paper's baseline) — HBM traffic includes the S/P
  round-trips AND every padded token.
* **packed-fused**: all documents concatenated into a few long rows with
  ``segment_ids``; the fused kernel masks cross-segment pairs and skips blocks
  whose segment ranges cannot intersect — 3-reads + 1-write I/O on only the
  *real* tokens.

The container is CPU-only, so wall-clock numbers time the *algorithms* (XLA
impls; pass --impl pallas_interpret to run the actual kernels, slower). The HBM
byte model is the paper's I/O accounting from benchmarks/common.py.

    PYTHONPATH=src python benchmarks/mha_varlen.py [--impl xla] [--docs 16]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from common import mha_hbm_bytes, row, time_fn
from repro.core.attention import spark_attention


def make_docs(rs, n_docs, min_len, max_len):
    return [int(x) for x in rs.randint(min_len, max_len + 1, size=n_docs)]


def pack_rows(lengths, row_len):
    """First-fit packing of doc lengths into rows of row_len. Returns
    (segment_ids [n_rows, row_len] int32, padding fraction)."""
    assert max(lengths) <= row_len, (
        f"doc of {max(lengths)} tokens cannot pack into rows of {row_len} "
        f"(raise --row-len or lower --max-len)")
    rows_ = [[]]
    for L in sorted(lengths, reverse=True):
        for r in rows_:
            if sum(r) + L <= row_len:
                r.append(L)
                break
        else:
            rows_.append([L])
    seg = np.full((len(rows_), row_len), -1, np.int32)
    sid = 0
    for i, r in enumerate(rows_):
        t = 0
        for L in r:
            seg[i, t:t + L] = sid
            sid += 1
            t += L
    pad_frac = float((seg < 0).mean())
    return seg, pad_frac


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "naive", "pallas_interpret"])
    ap.add_argument("--docs", type=int, default=16)
    ap.add_argument("--min-len", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=448)
    ap.add_argument("--row-len", type=int, default=512)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--head-dim", type=int, default=64)
    args = ap.parse_args()

    rs = np.random.RandomState(0)
    lengths = make_docs(rs, args.docs, args.min_len, args.max_len)
    h, d = args.heads, args.head_dim
    total = sum(lengths)
    max_len = max(lengths)

    # ---- padded-naive: one row per doc, padded to the longest doc ----
    bp = len(lengths)
    qp = jnp.asarray(rs.randn(bp, h, max_len, d), jnp.float32)
    pad_naive = jax.jit(lambda q, k, v: spark_attention(
        q, k, v, impl="naive", causal=True))
    us_padded = time_fn(pad_naive, qp, qp, qp)
    bytes_padded = mha_hbm_bytes(bp, h, h, max_len, max_len, d,
                                 fused=False, dtype_bytes=4)

    # ---- packed-fused: segment-packed rows + segment-masked fused attention
    seg, pad_frac = pack_rows(lengths, args.row_len)
    bq = seg.shape[0]
    qk = jnp.asarray(rs.randn(bq, h, args.row_len, d), jnp.float32)
    segj = jnp.asarray(seg)
    packed = jax.jit(lambda q, k, v: spark_attention(
        q, k, v, impl=args.impl, causal=True, segment_ids=segj,
        xla_chunk=128, block_q=128, block_kv=128))
    us_packed = time_fn(packed, qk, qk, qk)
    bytes_packed = mha_hbm_bytes(bq, h, h, args.row_len, args.row_len, d,
                                 fused=True, dtype_bytes=4)

    print(f"# {args.docs} docs of {args.min_len}..{args.max_len} tokens "
          f"(total {total}); padded batch [{bp}, {max_len}] vs "
          f"packed [{bq}, {args.row_len}] ({pad_frac:.1%} pad), impl={args.impl}")
    row("mha_varlen/padded_naive", us_padded, f"hbm_bytes={bytes_padded}")
    row("mha_varlen/packed_fused", us_packed, f"hbm_bytes={bytes_packed}")
    row("mha_varlen/hbm_ratio", 0.0,
        f"padded/packed={bytes_padded / bytes_packed:.2f}x")
    row("mha_varlen/step_ratio", 0.0,
        f"padded/packed={us_padded / us_packed:.2f}x")


if __name__ == "__main__":
    main()
