"""Aggregate the dry-run artifacts into the roofline summary table.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and prints:
  * the per-cell three-term roofline table (single-pod),
  * the dominant bottleneck + one-line 'what would move it',
  * the multi-pod compile matrix,
  * hillclimb-candidate ranking (worst MFU / most collective-bound).
"""

from __future__ import annotations

import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")

MOVE_HINTS = {
    "compute": "raise per-chip arithmetic intensity: larger per-device batch, "
               "bf16-ACC matmuls, fewer remat recomputes",
    "memory": "cut HBM traffic: fuse optimizer update, shard weights further "
              "(FSDP), reduce logits round-trips, bigger attention blocks",
    "collective": "reshape sharding: less TP for small models (SP all-gathers "
                  "dominate), reduce-scatter instead of all-reduce, int8 "
                  "gradient compression, overlap with compute",
}


def load(mesh="16x16", tag=""):
    rows = []
    for f in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}{tag}.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def fmt_table(rows):
    out = []
    hdr = (f"{'arch':22s} {'shape':12s} {'C(ms)':>8s} {'M(ms)':>8s} "
           f"{'X(ms)':>8s} {'bound':>10s} {'MFU%':>6s} {'useful':>6s} "
           f"{'mem/dev':>8s} {'fits':>5s}")
    out.append(hdr)
    out.append("-" * len(hdr))
    for r in rows:
        if r.get("skipped"):
            out.append(f"{r['arch']:22s} {r['shape']:12s} "
                       f"SKIP: {r['reason']}")
            continue
        if "error" in r:
            out.append(f"{r['arch']:22s} {r['shape']:12s} "
                       f"ERROR: {r['error'][:80]}")
            continue
        rf = r["roofline"]
        m = r["memory"]
        # fits_analytic (storage model) is authoritative when present: the
        # CPU scheduler's temp numbers overstate TPU residency (no donation
        # aliasing, different fusion/liveness)
        if "storage_analytic" in m:
            mem_gb = m["storage_analytic"]["total"] / 1e9
            fits = m["fits_analytic"]
        else:
            mem_gb = m["peak_estimate_bytes"] / 1e9
            fits = m["fits"]
        out.append(
            f"{r['arch']:22s} {r['shape']:12s} "
            f"{rf['compute_s']*1e3:8.1f} {rf['memory_s']*1e3:8.1f} "
            f"{rf['collective_s']*1e3:8.1f} {rf['bound']:>10s} "
            f"{rf['mfu']*100:6.1f} {rf['useful_compute_ratio']:6.2f} "
            f"{mem_gb:7.2f}G "
            f"{'yes' if fits else 'NO':>5s}")
    return "\n".join(out)


def _dir_rows(dirname, mesh="16x16"):
    import glob as g
    base = os.path.join(os.path.dirname(ART_DIR), dirname)
    rows = {}
    for f in sorted(g.glob(os.path.join(base, f"*__{mesh}.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if "roofline" in r:
            rows[(r["arch"], r["shape"])] = r
    return rows


def evolution_table():
    """v0 (paper-faithful baseline) → v1 (bug fixes) → v2 (optimized)."""
    dirs = [("v0", "dryrun_baseline"), ("v1", "dryrun_v1"), ("v2", "dryrun")]
    tables = [(tag, _dir_rows(d)) for tag, d in dirs
              if os.path.isdir(os.path.join(os.path.dirname(ART_DIR), d))]
    if len(tables) < 2:
        return
    print("== Perf evolution: step-time roofline (ms) and MFU per version ==")
    keys = sorted(set().union(*[t.keys() for _, t in tables]))
    hdr = f"{'cell':36s}" + "".join(f" {tag+'(ms)':>10s} {tag+'%':>6s}"
                                    for tag, _ in tables)
    print(hdr)
    for k in keys:
        line = f"{k[0]+' '+k[1]:36s}"
        for _, t in tables:
            r = t.get(k)
            if r:
                rf = r["roofline"]
                line += f" {rf['step_time_s']*1e3:10.1f} {rf['mfu']*100:6.1f}"
            else:
                line += f" {'-':>10s} {'-':>6s}"
        print(line)
    print()


def main():
    rows = load("16x16")
    print("== Roofline (single-pod 16x16, 256 chips) ==")
    print(fmt_table(rows))
    print()
    evolution_table()
    ok = [r for r in rows if "roofline" in r]
    if ok:
        print("== Bottleneck hints ==")
        for r in ok:
            rf = r["roofline"]
            print(f"{r['arch']:22s} {r['shape']:12s} {rf['bound']:>10s}: "
                  f"{MOVE_HINTS[rf['bound']]}")
        print()
        print("== Hillclimb candidates ==")
        worst = sorted(ok, key=lambda r: r["roofline"]["mfu"])[:3]
        coll = sorted(ok, key=lambda r: -r["roofline"]["collective_s"])[:3]
        print("worst MFU:", [(r["arch"], r["shape"],
                              f"{r['roofline']['mfu']*100:.1f}%")
                             for r in worst])
        print("most collective-bound:",
              [(r["arch"], r["shape"],
                f"{r['roofline']['collective_s']*1e3:.0f}ms") for r in coll])
    mrows = load("2x16x16")
    if mrows:
        print()
        print("== Multi-pod (2x16x16, 512 chips) compile matrix ==")
        for r in mrows:
            status = ("SKIP" if r.get("skipped")
                      else "FAIL" if "error" in r else "OK")
            extra = ""
            if status == "OK":
                extra = (f"compile={r['compile_s']:.0f}s "
                         f"mem/dev={r['memory']['peak_estimate_bytes']/1e9:.2f}G")
            print(f"{status:5s} {r['arch']:22s} {r['shape']:12s} {extra}")


if __name__ == "__main__":
    main()
