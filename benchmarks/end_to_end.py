"""Paper Figure 12: End-to-End Encoder-Forward with fused vs unfused MHA.

The paper replaces ONLY the MHA-Forward inside a single traditional encoder
layer ("control variable method") and measures the layer end to end. We do the
same with the hubert-style encoder block: naive attention vs the fused online
algorithm, plus the full-model smoke variant.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro import configs
from repro.models import lm
from repro.models.layers import Ctx

HID = 256


def encoder_cfg(seq):
    base = configs.smoke_config("hubert_xlarge")
    return dataclasses.replace(
        base, num_layers=1, d_model=HID, num_heads=HID // 64, num_kv_heads=HID // 64,
        d_ff=4 * HID, vocab_size=128, dtype=jnp.float32, remat=False)


def main():
    for seq in (512, 1024, 2048):
        cfg = encoder_cfg(seq)
        params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
        embeds = jax.random.normal(jax.random.PRNGKey(1),
                                   (2, seq, lm.FRONTEND_DIM))

        def fwd(impl, p, e):
            ctx = Ctx(impl=impl, xla_chunk=min(512, seq))
            logits, _, _ = lm.forward(cfg, p, ctx, embeds=e)
            return logits

        fused = jax.jit(functools.partial(fwd, "xla"))
        naive = jax.jit(functools.partial(fwd, "naive"))
        us_f = time_fn(fused, params, embeds)
        us_n = time_fn(naive, params, embeds)
        row(f"e2e_encoder_fused_seq{seq}", us_f, f"speedup={us_n/us_f:.2f}x")
        row(f"e2e_encoder_naive_seq{seq}", us_n, "")


if __name__ == "__main__":
    main()
