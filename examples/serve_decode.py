"""Serving example: batched prefill + autoregressive decode with KV cache.

Part 1 exercises the flash-decode path (ragged batch lengths, GQA-packed MXU
rows) end to end with greedy sampling, and verifies the generation is
identical to teacher-forcing the same tokens through the full forward pass.

Part 2 serves ragged requests through the paged-KV subsystem (block-table
cache + continuous batching + segment-aware packed prefill) and verifies the
generations match the contiguous path exactly — same logits, different cache
layout.  See docs/serving.md.

    PYTHONPATH=src python examples/serve_decode.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm
from repro.models.layers import Ctx
from repro.runtime.steps import make_serve_steps
from repro.serving import PagedCacheConfig, ServingEngine

cfg = dataclasses.replace(configs.smoke_config("qwen3_14b"),
                          dtype=jnp.float32, remat=False)
B, PROMPT, GEN = 2, 48, 24
arts = make_serve_steps(cfg, impl="xla", max_len=PROMPT + GEN, batch=B,
                        xla_chunk=16)
params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
prompt = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0,
                            cfg.vocab_size)

caches = arts.cache_init_fn()
logits, caches = arts.prefill_fn(params, prompt, None, caches)
tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
generated = [np.asarray(tok)]
for i in range(GEN - 1):
    logits, caches = arts.decode_fn(params, tok, caches, jnp.int32(PROMPT + i))
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
    generated.append(np.asarray(tok))
gen = np.stack(generated, axis=1)
print("generated tokens (row 0):", gen[0])

# verification: teacher-force the generated sequence; argmax must reproduce it
full = jnp.concatenate([prompt, jnp.asarray(gen)], axis=1)
logits_full, _, _ = lm.forward(cfg, params, Ctx(impl="xla", xla_chunk=16),
                               tokens=full)
pred = np.asarray(jnp.argmax(logits_full[:, :, :cfg.vocab_size], axis=-1))
match = (pred[:, PROMPT - 1:-1] == gen).mean()
print(f"teacher-forcing agreement: {match*100:.1f}% (expect 100%)")
assert match == 1.0

# ---------------------------------------------------------------------------
# Part 2: the same model served through the paged-KV subsystem. Ragged
# prompts/budgets, a page pool too small for every request at once (so the
# scheduler actually runs admission waves), packed prefill. Row 0 reuses the
# prompt from part 1, so its generation must reproduce `gen[0]` exactly.
# ---------------------------------------------------------------------------
pcfg = PagedCacheConfig(page_size=8, num_pages=24, max_batch=2,
                        max_pages_per_seq=9)
engine = ServingEngine(cfg, pcfg, params, impl="xla", prefill_len=64,
                       xla_chunk=16)
rs = np.random.RandomState(2)
requests = [(np.asarray(prompt[0]), GEN)] + [
    (rs.randint(0, cfg.vocab_size, size=int(rs.randint(4, 40))), int(rs.randint(1, 16)))
    for _ in range(4)]
out, stats = engine.run(requests)
print(f"paged serving: {len(out)} ragged requests, "
      f"{stats['generated_tokens']:.0f} tokens in {stats['decode_steps']:.0f} "
      f"decode steps, cache utilization {stats['mean_utilization']:.1%}")
assert np.array_equal(out[0], gen[0]), "paged must match the contiguous path"
print("paged generation of request 0 == contiguous generation: True")
