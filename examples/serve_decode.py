"""Serving example: batched prefill + autoregressive decode with KV cache.

Exercises the flash-decode path (ragged batch lengths, GQA-packed MXU rows)
end to end with greedy sampling, and verifies the generation is identical to
teacher-forcing the same tokens through the full forward pass.

    PYTHONPATH=src python examples/serve_decode.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm
from repro.models.layers import Ctx
from repro.runtime.steps import make_serve_steps

cfg = dataclasses.replace(configs.smoke_config("qwen3_14b"),
                          dtype=jnp.float32, remat=False)
B, PROMPT, GEN = 2, 48, 24
arts = make_serve_steps(cfg, impl="xla", max_len=PROMPT + GEN, batch=B,
                        xla_chunk=16)
params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
prompt = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0,
                            cfg.vocab_size)

caches = arts.cache_init_fn()
logits, caches = arts.prefill_fn(params, prompt, None, caches)
tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
generated = [np.asarray(tok)]
for i in range(GEN - 1):
    logits, caches = arts.decode_fn(params, tok, caches, jnp.int32(PROMPT + i))
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
    generated.append(np.asarray(tok))
gen = np.stack(generated, axis=1)
print("generated tokens (row 0):", gen[0])

# verification: teacher-force the generated sequence; argmax must reproduce it
full = jnp.concatenate([prompt, jnp.asarray(gen)], axis=1)
logits_full, _, _ = lm.forward(cfg, params, Ctx(impl="xla", xla_chunk=16),
                               tokens=full)
pred = np.asarray(jnp.argmax(logits_full[:, :, :cfg.vocab_size], axis=-1))
match = (pred[:, PROMPT - 1:-1] == gen).mean()
print(f"teacher-forcing agreement: {match*100:.1f}% (expect 100%)")
assert match == 1.0
