"""Long-context decode with the hybrid (RG-LRU + local attention) arch.

Demonstrates why the long_500k cell is assigned to sub-quadratic archs: the
recurrentgemma-style ring KV cache stays at `window` slots while the RG-LRU
state carries unbounded context — decoding step cost is O(window), constant in
context length. We decode far past the window and show (a) constant cache
size, (b) the recurrence is actually carrying long-range state.

    PYTHONPATH=src python examples/long_context_hybrid.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm
from repro.models.layers import Ctx

cfg = dataclasses.replace(configs.smoke_config("recurrentgemma_2b"),
                          dtype=jnp.float32, remat=False)
print(f"arch: {cfg.name} window={cfg.attn_window} pattern={cfg.block_pattern}")

params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
ctx = Ctx(impl="xla", xla_chunk=16, block_kv=16)

B, PROMPT, GEN = 1, 64, 96          # decode 3× past the 32-token window
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT + GEN), 0,
                            cfg.vocab_size)
caches = lm.init_cache(cfg, B, PROMPT + GEN)
kv_shapes = [x.shape for x in jax.tree.leaves(caches)
             if hasattr(x, "ndim") and x.ndim == 5]
print("attention cache blocks:", kv_shapes, f"(seq dim == window == {cfg.attn_window})")

logits_full, _, _ = lm.forward(cfg, params, ctx, tokens=tokens)
_, caches = lm.prefill(cfg, params, ctx, tokens=tokens[:, :PROMPT],
                       caches=caches)
errs = []
for t in range(GEN):
    pos = PROMPT + t
    lg, caches = lm.decode_step(cfg, params, ctx, tokens[:, pos], caches, pos)
    errs.append(float(jnp.abs(lg - logits_full[:, pos]).max()))
print(f"decode-vs-teacher-forced max err over {GEN} steps "
      f"(ring wraps at step {cfg.attn_window - (PROMPT % cfg.attn_window)}): "
      f"{max(errs):.2e}")
assert max(errs) < 2e-3

# long-range signal: perturb a token far OUTSIDE the attention window of the
# last position; with pure local attention the final logits could not change —
# the RG-LRU state is what carries it.
tokens2 = tokens.at[:, 4].set((tokens[:, 4] + 7) % cfg.vocab_size)
lf2, _, _ = lm.forward(cfg, params, ctx, tokens=tokens2)
delta = float(jnp.abs(lf2[:, -1] - logits_full[:, -1]).max())
print(f"perturbing token@4 (≫window before the end) changes final logits by "
      f"{delta:.2e} → recurrent state carries long-range context")
assert delta > 0
