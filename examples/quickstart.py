"""Quickstart: SparkAttention as a drop-in fused attention module.

Runs on CPU (kernels in interpret mode). Shows the three execution paths
giving identical results and the paper's two accumulate-precision variants.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import spark_attention

B, H, HKV, S, D = 2, 8, 2, 512, 64
key = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (B, H, S, D))
k = jax.random.normal(kk, (B, HKV, S, D))   # GQA: 4 query heads per KV head
v = jax.random.normal(kv, (B, HKV, S, D))

# 1) the fused Pallas kernel (interpret mode on CPU; compiled on TPU)
o_kernel = spark_attention(q, k, v, impl="pallas_interpret", causal=True)

# 2) the same algorithm in plain XLA (what the multi-pod dry-run lowers)
o_xla = spark_attention(q, k, v, impl="xla", causal=True)

# 3) the unfused baseline (the paper's PyTorch/cuBLAS comparison point)
o_naive = spark_attention(q, k, v, impl="naive", causal=True)

print("kernel vs naive :", float(jnp.abs(o_kernel - o_naive).max()))
print("xla    vs naive :", float(jnp.abs(o_xla - o_naive).max()))

# the paper's FP16-ACC vs FP32-ACC tradeoff (bf16 on TPU)
q16, k16, v16 = (x.astype(jnp.bfloat16) for x in (q, k, v))
o_f32acc = spark_attention(q16, k16, v16, impl="pallas_interpret", causal=True,
                           acc_dtype=jnp.float32)
o_b16acc = spark_attention(q16, k16, v16, impl="pallas_interpret", causal=True,
                           acc_dtype=jnp.bfloat16)
ref = np.asarray(o_naive, np.float32)
print("f32-ACC err    :", np.abs(np.asarray(o_f32acc, np.float32) - ref).max())
print("bf16-ACC err   :", np.abs(np.asarray(o_b16acc, np.float32) - ref).max())

# gradients flow through the custom_vjp (backward = dual-pass recompute kernel)
def loss(q, k, v):
    return jnp.sum(spark_attention(q, k, v, impl="pallas_interpret",
                                   causal=True) ** 2)

g = jax.grad(loss)(q, k, v)
print("grad ok, |dq| =", float(jnp.abs(g).mean()))
