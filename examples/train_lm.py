"""End-to-end driver: train a ~100M-param GQA LM for a few hundred steps.

Uses the real production stack — config system, sharding-aware step builder,
fault-tolerant trainer with checkpointing — on CPU with a reduced config.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import os
import shutil

import jax.numpy as jnp

from repro import configs
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.runtime.steps import make_train_step
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--pack", action="store_true",
                    help="train on segment-packed (varlen) batches: each row "
                         "packs several short documents, attention and the LM "
                         "loss stay within document boundaries")
    ap.add_argument("--min-seg-len", type=int, default=16)
    ap.add_argument("--max-seg-len", type=int, default=96)
    args = ap.parse_args()
    if not args.resume:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    # ~100M params: granite family at width 512 / 12 layers / 32k vocab
    cfg = dataclasses.replace(
        configs.get_config("granite_3_2b"),
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=2, d_ff=2048,
        vocab_size=32768, dtype=jnp.float32, dropout_rate=0.0)
    n = cfg.param_count()
    print(f"model: {cfg.name}-mini, {n/1e6:.1f}M params")

    arts = make_train_step(cfg, opt=AdamWConfig(lr=6e-4, weight_decay=0.1),
                           impl="xla", total_steps=args.steps,
                           warmup_steps=30, xla_chunk=128)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=256,
                          global_batch=4, pack=args.pack,
                          min_seg_len=args.min_seg_len,
                          max_seg_len=args.max_seg_len)
    if args.pack:
        print(f"packing: segments of {args.min_seg_len}..{args.max_seg_len} "
              f"tokens per 256-token row (segment-masked attention + loss)")
    trainer = Trainer(arts=arts, data_cfg=data_cfg,
                      tcfg=TrainerConfig(ckpt_dir=args.ckpt_dir,
                                         ckpt_every=100, log_every=10))
    result = trainer.run(args.steps)
    first = trainer.metrics_log[0]["loss"] if trainer.metrics_log else None
    last = trainer.metrics_log[-1]["loss"] if trainer.metrics_log else None
    print(f"loss: {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({len(result['stragglers'])} straggler steps flagged)")


if __name__ == "__main__":
    main()
