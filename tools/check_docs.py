#!/usr/bin/env python
"""Docs drift guard (CI `docs` job; run locally with `python tools/check_docs.py`).

Four cheap checks that catch the usual ways docs rot:

1. every relative markdown link in README.md and docs/*.md resolves to a file
   or directory in the repo (anchors and external URLs are skipped);
2. every package under src/repro/ is mentioned in docs/architecture.md, so a
   new subsystem cannot land undocumented;
3. every ``*.md`` file referenced from Python source (docstrings/comments —
   e.g. "see docs/serving.md") exists in the repo, so code cannot keep
   pointing readers at deleted design notes (the seed's docstrings cited two
   long-gone design/experiment logs for two PRs);
4. docstring coverage over the packages whose behaviour the docs narrate in
   detail (``serving/``, ``kernels/``, ``perf/``): every public module,
   public top-level function/class and public method must carry a docstring —
   an undocumented entry point there is exactly the drift the
   scheduling/kernels/roofline docs would silently diverge around.

Exit code 0 = clean; 1 = drift, with one line per problem.
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))   # script invocation: make tools.* importable

from tools.analysis.core import AstCache  # noqa: E402

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list:
    problems = []
    md_files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    for md in md_files:
        if not md.exists():
            problems.append(f"{md.relative_to(ROOT)}: file missing")
            continue
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path = target.split("#", 1)[0]
                if not path:      # pure in-page anchor
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    problems.append(
                        f"{md.relative_to(ROOT)}:{lineno}: dead link "
                        f"'{target}'")
    return problems


def check_architecture_coverage() -> list:
    arch = ROOT / "docs" / "architecture.md"
    if not arch.exists():
        return ["docs/architecture.md: file missing"]
    text = arch.read_text()
    problems = []
    for pkg in sorted((ROOT / "src" / "repro").iterdir()):
        if not pkg.is_dir() or pkg.name.startswith("__"):
            continue
        if f"{pkg.name}/" not in text and f"`{pkg.name}`" not in text:
            problems.append(
                f"docs/architecture.md: package src/repro/{pkg.name} is "
                f"not mentioned")
    return problems


MD_REF_RE = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.md\b")
PY_DIRS = ("src", "tests", "tools", "benchmarks", "examples")


def check_py_doc_refs(cache: AstCache) -> list:
    """Flag repo-doc (.md) references in Python files that resolve nowhere.

    A reference counts as resolved if it exists relative to the repo root,
    the referencing file's directory, or docs/ (prose often drops the docs/
    prefix). Dotted module paths that merely end in ".md" cannot occur — the
    regex requires the .md to terminate the token. Files come from the shared
    sparklint AST cache (``tools/analysis``) so the docs job and the lint
    job read one analysis substrate.
    """
    problems = []
    for sf in cache.iter_python(*PY_DIRS):
        for lineno, line in enumerate(sf.lines, 1):
            for ref in MD_REF_RE.findall(line):
                name = ref.lstrip("./")
                candidates = (ROOT / name, sf.path.parent / name,
                              ROOT / "docs" / name)
                if not any(c.exists() for c in candidates):
                    problems.append(
                        f"{sf.rel}:{lineno}: reference to "
                        f"nonexistent repo doc '{ref}'")
    return problems


# packages with doc pages narrating their internals — keep the code
# self-describing so the narration has something stable to point at
# (tools/analysis: docs/analysis.md narrates every sparklint rule)
DOCSTRING_PKGS = ("src/repro/serving", "src/repro/kernels", "src/repro/perf",
                  "tools/analysis")


def _missing_docstrings(tree: ast.Module, relpath: str) -> list:
    """Public defs in one parsed module that lack a docstring.

    Public = name without a leading underscore; for classes the check
    recurses one level into public methods (``__init__`` counts as private —
    dataclasses and trivial constructors are described by the class).
    """
    name = Path(relpath).name
    public_module = name == "__init__.py" or not name.startswith("_")
    problems = []
    if public_module and ast.get_docstring(tree) is None:
        problems.append(f"{relpath}:1: public module has no docstring")

    def visit(node, prefix=""):
        for child in node.body:
            if not isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef)):
                continue
            if child.name.startswith("_"):
                continue
            kind = "class" if isinstance(child, ast.ClassDef) else "function"
            if ast.get_docstring(child) is None:
                problems.append(
                    f"{relpath}:{child.lineno}: public {kind} "
                    f"'{prefix}{child.name}' has no docstring")
            if isinstance(child, ast.ClassDef):
                visit(child, prefix=f"{child.name}.")

    visit(tree)
    return problems


def check_docstring_coverage(cache: AstCache) -> list:
    """Every public module/function/class/method in DOCSTRING_PKGS has a
    docstring (private names and non-Python files are skipped). Parsed
    modules come from the shared sparklint AST cache — each file is parsed
    once per run, no private parsing loop here."""
    problems = []
    for pkg in DOCSTRING_PKGS:
        if not (ROOT / pkg).is_dir():
            problems.append(f"{pkg}: package missing")
            continue
        for sf in cache.iter_python(pkg):
            if sf.tree is None:
                problems.append(f"{sf.rel}: unparsable ({sf.parse_error})")
                continue
            problems.extend(_missing_docstrings(sf.tree, sf.rel))
    return problems


def main() -> int:
    cache = AstCache(ROOT)
    problems = (check_links() + check_architecture_coverage()
                + check_py_doc_refs(cache) + check_docstring_coverage(cache))
    for p in problems:
        print(p)
    print(f"check_docs: {'FAIL' if problems else 'ok'} "
          f"({len(problems)} problem(s))")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
