#!/usr/bin/env python
"""Docs drift guard (CI `docs` job; run locally with `python tools/check_docs.py`).

Three cheap checks that catch the usual ways docs rot:

1. every relative markdown link in README.md and docs/*.md resolves to a file
   or directory in the repo (anchors and external URLs are skipped);
2. every package under src/repro/ is mentioned in docs/architecture.md, so a
   new subsystem cannot land undocumented;
3. every ``*.md`` file referenced from Python source (docstrings/comments —
   e.g. "see docs/serving.md") exists in the repo, so code cannot keep
   pointing readers at deleted design notes (the seed's docstrings cited two
   long-gone design/experiment logs for two PRs).

Exit code 0 = clean; 1 = drift, with one line per problem.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list:
    problems = []
    md_files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    for md in md_files:
        if not md.exists():
            problems.append(f"{md.relative_to(ROOT)}: file missing")
            continue
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path = target.split("#", 1)[0]
                if not path:      # pure in-page anchor
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    problems.append(
                        f"{md.relative_to(ROOT)}:{lineno}: dead link "
                        f"'{target}'")
    return problems


def check_architecture_coverage() -> list:
    arch = ROOT / "docs" / "architecture.md"
    if not arch.exists():
        return ["docs/architecture.md: file missing"]
    text = arch.read_text()
    problems = []
    for pkg in sorted((ROOT / "src" / "repro").iterdir()):
        if not pkg.is_dir() or pkg.name.startswith("__"):
            continue
        if f"{pkg.name}/" not in text and f"`{pkg.name}`" not in text:
            problems.append(
                f"docs/architecture.md: package src/repro/{pkg.name} is "
                f"not mentioned")
    return problems


MD_REF_RE = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.md\b")
PY_DIRS = ("src", "tests", "tools", "benchmarks", "examples")


def check_py_doc_refs() -> list:
    """Flag repo-doc (.md) references in Python files that resolve nowhere.

    A reference counts as resolved if it exists relative to the repo root,
    the referencing file's directory, or docs/ (prose often drops the docs/
    prefix). Dotted module paths that merely end in ".md" cannot occur — the
    regex requires the .md to terminate the token.
    """
    problems = []
    for d in PY_DIRS:
        base = ROOT / d
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            if "__pycache__" in py.parts:
                continue
            for lineno, line in enumerate(py.read_text().splitlines(), 1):
                for ref in MD_REF_RE.findall(line):
                    name = ref.lstrip("./")
                    candidates = (ROOT / name, py.parent / name,
                                  ROOT / "docs" / name)
                    if not any(c.exists() for c in candidates):
                        problems.append(
                            f"{py.relative_to(ROOT)}:{lineno}: reference to "
                            f"nonexistent repo doc '{ref}'")
    return problems


def main() -> int:
    problems = (check_links() + check_architecture_coverage()
                + check_py_doc_refs())
    for p in problems:
        print(p)
    print(f"check_docs: {'FAIL' if problems else 'ok'} "
          f"({len(problems)} problem(s))")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
