"""Repo tooling: docs drift guard (check_docs) + sparklint (analysis/)."""
