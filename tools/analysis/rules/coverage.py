"""Coverage contracts: surfaces the test suite must track by construction.

``ops-test-coverage``: every public op entrypoint is exercised by tests.
``kernels/ops.py`` is the public surface the oracle tests pin — an
entrypoint no test references is an entrypoint whose kernel/fallback/oracle
agreement can silently rot (exactly how the seed's decode variants diverged
before the PR 5 unification). The rule cross-references every public
top-level def/class in ops.py against the identifier sets of ``tests/``.

``config-zoo-coverage``: every config name in ``configs.ARCHS`` appears in
the serving conformance matrix ``tests/test_config_zoo.py``. Adding a
config without slotting it into the zoo is how an architecture ships with
serving silently unverified — the matrix only certifies what it names.
"""

from __future__ import annotations

import ast
from typing import List, Set

from tools.analysis.core import Finding, rule

OPS_PATH = "src/repro/kernels/ops.py"


def _test_identifiers(cache) -> Set[str]:
    """Every Name id and Attribute attr appearing in any tests/*.py file."""
    idents: Set[str] = set()
    for sf in cache.iter_python("tests"):
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Name):
                idents.add(node.id)
            elif isinstance(node, ast.Attribute):
                idents.add(node.attr)
            elif isinstance(node, ast.ImportFrom):
                idents.update(a.name for a in node.names)
    return idents


@rule("ops-test-coverage",
      description="every public entrypoint in kernels/ops.py is referenced "
                  "by at least one test file",
      paths=(OPS_PATH,))
def ops_test_coverage(cache, sf) -> List[Finding]:
    """Flag public top-level defs/classes in ops.py absent from tests/."""
    idents = _test_identifiers(cache)
    out = []
    for node in sf.tree.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            continue
        if node.name.startswith("_"):
            continue
        if node.name not in idents:
            kind = "class" if isinstance(node, ast.ClassDef) else "function"
            out.append(Finding(
                "ops-test-coverage", sf.rel, node.lineno,
                f"public {kind} '{node.name}' is not referenced by any "
                f"test file — add an oracle test or make it private"))
    return out


CONFIGS_PATH = "src/repro/configs/__init__.py"
ZOO_TEST = "tests/test_config_zoo.py"


def _zoo_strings(cache):
    """All string constants in the zoo test file (None if it is absent)."""
    for sf in cache.iter_python("tests"):
        if sf.rel == ZOO_TEST and sf.tree is not None:
            return {node.value for node in ast.walk(sf.tree)
                    if isinstance(node, ast.Constant)
                    and isinstance(node.value, str)}
    return None


@rule("config-zoo-coverage",
      description="every config name in configs.ARCHS appears in the "
                  "serving conformance matrix tests/test_config_zoo.py",
      paths=(CONFIGS_PATH,))
def config_zoo_coverage(cache, sf) -> List[Finding]:
    """Flag ARCHS entries absent from the zoo matrix (string-constant scan:
    the zoo names archs literally in parametrize lists, so a plain constant
    search is exact — no need to evaluate the test module)."""
    archs = []
    lines = {}
    for node in sf.tree.body:
        if not isinstance(node, ast.Assign):
            continue
        if not any(isinstance(t, ast.Name) and t.id == "ARCHS"
                   for t in node.targets):
            continue
        if isinstance(node.value, (ast.List, ast.Tuple)):
            for elt in node.value.elts:
                if isinstance(elt, ast.Constant) and isinstance(elt.value,
                                                                str):
                    archs.append(elt.value)
                    lines[elt.value] = elt.lineno
    if not archs:
        return []
    zoo = _zoo_strings(cache)
    if zoo is None:
        return [Finding(
            "config-zoo-coverage", sf.rel, lines[archs[0]],
            f"{ZOO_TEST} is missing — the serving conformance matrix must "
            f"cover every config in ARCHS")]
    return [Finding(
        "config-zoo-coverage", sf.rel, lines[name],
        f"config '{name}' is not named in {ZOO_TEST} — add it to the "
        f"serving conformance matrix (or to its encoder/slow tier)")
        for name in archs if name not in zoo]
