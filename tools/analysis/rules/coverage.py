"""Oracle-coverage contract: every public op entrypoint is exercised by tests.

``kernels/ops.py`` is the public surface the oracle tests pin — an
entrypoint no test references is an entrypoint whose kernel/fallback/oracle
agreement can silently rot (exactly how the seed's decode variants diverged
before the PR 5 unification). The rule cross-references every public
top-level def/class in ops.py against the identifier sets of ``tests/``.
"""

from __future__ import annotations

import ast
from typing import List, Set

from tools.analysis.core import Finding, rule

OPS_PATH = "src/repro/kernels/ops.py"


def _test_identifiers(cache) -> Set[str]:
    """Every Name id and Attribute attr appearing in any tests/*.py file."""
    idents: Set[str] = set()
    for sf in cache.iter_python("tests"):
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Name):
                idents.add(node.id)
            elif isinstance(node, ast.Attribute):
                idents.add(node.attr)
            elif isinstance(node, ast.ImportFrom):
                idents.update(a.name for a in node.names)
    return idents


@rule("ops-test-coverage",
      description="every public entrypoint in kernels/ops.py is referenced "
                  "by at least one test file",
      paths=(OPS_PATH,))
def ops_test_coverage(cache, sf) -> List[Finding]:
    """Flag public top-level defs/classes in ops.py absent from tests/."""
    idents = _test_identifiers(cache)
    out = []
    for node in sf.tree.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            continue
        if node.name.startswith("_"):
            continue
        if node.name not in idents:
            kind = "class" if isinstance(node, ast.ClassDef) else "function"
            out.append(Finding(
                "ops-test-coverage", sf.rel, node.lineno,
                f"public {kind} '{node.name}' is not referenced by any "
                f"test file — add an oracle test or make it private"))
    return out
