"""sparklint rule modules — importing this package registers every rule.

Each module guards one layer's invariants (see each module's docstring for
the motivating bug, and docs/analysis.md for the full catalogue):

* :mod:`.kernels`  — fold routing, launch helper, f32 state, NEG_INF source
* :mod:`.serving`  — host layer stays numpy/python
* :mod:`.runtime`  — page-pool donation + donated-binding def-use
* :mod:`.configs`  — fsdp profile/flag gate
* :mod:`.coverage` — ops.py entrypoints are test-referenced
"""

from tools.analysis.rules import (configs, coverage, kernels,  # noqa: F401
                                  runtime, serving)
