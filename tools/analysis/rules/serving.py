"""Serving host-layer contract: the scheduler stack is device-free.

The PR 4 invariant: the scheduler state machine, the page allocator/block
tables, the recurrent-state slot cache, and the drafter run on the host in
plain numpy/python — the only device work per engine step is the
fixed-shape jitted calls in ``runtime/steps.py``. A stray ``jax``/``jnp``
import here is how host bookkeeping silently starts tracing, recompiling
per queue shape, or holding device buffers the allocator thinks it freed.
"""

from __future__ import annotations

import ast
from typing import List

from tools.analysis.core import Finding, enclosing_functions, rule

#: the host-only modules (engine.py is the device boundary and is exempt)
HOST_ONLY = ("src/repro/serving/scheduler.py",
             "src/repro/serving/paged_cache.py",
             "src/repro/serving/state_cache.py",
             "src/repro/serving/drafter.py",
             "src/repro/serving/outcomes.py",
             "src/repro/serving/faults.py")

BANNED_ROOTS = {"jax", "jaxlib"}


@rule("host-layer-numpy-only",
      description="serving host layer (scheduler/paged_cache/drafter) "
                  "imports no jax — numpy/python only",
      paths=HOST_ONLY)
def host_layer_numpy_only(cache, sf) -> List[Finding]:
    """Flag any import of jax/jaxlib (incl. ``from jax import …``)."""
    out = []
    for node in ast.walk(sf.tree):
        mods = []
        if isinstance(node, ast.Import):
            mods = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            mods = [node.module]
        for mod in mods:
            if mod.split(".")[0] in BANNED_ROOTS:
                out.append(Finding(
                    "host-layer-numpy-only", sf.rel, node.lineno,
                    f"import of '{mod}' in the serving host layer — "
                    f"scheduler/paged_cache/drafter stay numpy/python "
                    f"(device work belongs in the jitted steps)"))
    return out


#: calls that remove an active sequence's resources in the engine; functions
#: making them must also record a typed outcome (or funnel through a helper
#: that does), else requests silently vanish from the books
_REMOVAL_ATTRS = {"release", "evict_finished"}
_OUTCOME_MARKERS = {"Outcome", "_record_outcome"}


@rule("engine-outcome-taxonomy",
      description="every engine code path that removes an active sequence "
                  "records a typed request outcome",
      paths=("src/repro/serving/engine.py",))
def engine_outcome_taxonomy(cache, sf) -> List[Finding]:
    """The PR 10 resilience contract: a request leaving the active set —
    ``tables.release(slot)`` or ``scheduler.evict_finished()`` — must end in
    exactly one typed outcome (``COMPLETED | CANCELLED | TIMEOUT | SHED |
    FAILED``).  Enforced structurally: any engine function making one of
    those removal calls must also reference ``Outcome`` or the
    ``_record_outcome`` funnel.  A removal call in a function with neither
    is a request that terminates untyped — the bug class where a cancel or
    quarantine path frees the pages but leaves the rid unaccounted."""
    out = []
    owners = enclosing_functions(sf.tree)
    # which functions reference an outcome marker anywhere in their body
    marked = set()
    for node in ast.walk(sf.tree):
        name = None
        if isinstance(node, ast.Name):
            name = node.id
        elif isinstance(node, ast.Attribute):
            name = node.attr
        if name in _OUTCOME_MARKERS and owners.get(node) is not None:
            marked.add(owners[node])
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _REMOVAL_ATTRS):
            continue
        fn = owners.get(node)
        if fn is None or fn not in marked:
            where = fn.name if fn is not None else "<module>"
            out.append(Finding(
                "engine-outcome-taxonomy", sf.rel, node.lineno,
                f"'{node.func.attr}(...)' in '{where}' removes an active "
                f"sequence without recording a typed outcome — route "
                f"through _record_outcome/_terminate_active so the request "
                f"terminates as COMPLETED/CANCELLED/TIMEOUT/SHED/FAILED"))
    return out
