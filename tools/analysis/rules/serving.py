"""Serving host-layer contract: the scheduler stack is device-free.

The PR 4 invariant: the scheduler state machine, the page allocator/block
tables, the recurrent-state slot cache, and the drafter run on the host in
plain numpy/python — the only device work per engine step is the
fixed-shape jitted calls in ``runtime/steps.py``. A stray ``jax``/``jnp``
import here is how host bookkeeping silently starts tracing, recompiling
per queue shape, or holding device buffers the allocator thinks it freed.
"""

from __future__ import annotations

import ast
from typing import List

from tools.analysis.core import Finding, rule

#: the host-only modules (engine.py is the device boundary and is exempt)
HOST_ONLY = ("src/repro/serving/scheduler.py",
             "src/repro/serving/paged_cache.py",
             "src/repro/serving/state_cache.py",
             "src/repro/serving/drafter.py")

BANNED_ROOTS = {"jax", "jaxlib"}


@rule("host-layer-numpy-only",
      description="serving host layer (scheduler/paged_cache/drafter) "
                  "imports no jax — numpy/python only",
      paths=HOST_ONLY)
def host_layer_numpy_only(cache, sf) -> List[Finding]:
    """Flag any import of jax/jaxlib (incl. ``from jax import …``)."""
    out = []
    for node in ast.walk(sf.tree):
        mods = []
        if isinstance(node, ast.Import):
            mods = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            mods = [node.module]
        for mod in mods:
            if mod.split(".")[0] in BANNED_ROOTS:
                out.append(Finding(
                    "host-layer-numpy-only", sf.rel, node.lineno,
                    f"import of '{mod}' in the serving host layer — "
                    f"scheduler/paged_cache/drafter stay numpy/python "
                    f"(device work belongs in the jitted steps)"))
    return out
