"""Runtime-step contract: page pools are donated, and donation is respected.

The KV cache / page pool is the dominant serving tensor; a jitted step that
takes it without donating doubles peak memory, and code that *reads* a
binding after passing it to a donating call dereferences a deleted buffer
(an error jax only raises at runtime, on the composition that hits it).
Two checks over ``runtime/steps.py``:

* every ``jax.jit`` whose wrapped function takes a pool-named parameter
  (``caches``/``pages``/``pool``/``page_pool``) lists that parameter in
  ``donate_argnums``;
* a def-use walk: any variable passed in a donated position of a call to a
  known-donating jitted callable is never read later in the same function
  without an intervening rebind.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from tools.analysis.core import (Finding, call_name, const_tuple,
                                 enclosing_functions, rule)

POOL_PARAMS = {"caches", "pages", "pool", "page_pool"}


def _function_defs(tree: ast.Module) -> List[ast.FunctionDef]:
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _resolve_def(defs: List[ast.FunctionDef], name: str,
                 use_line: int) -> Optional[ast.FunctionDef]:
    """The nearest def of ``name`` at or above ``use_line`` (lexical shadowing:
    two branches may each define a local ``prefill_fn``)."""
    best = None
    for d in defs:
        if d.name == name and d.lineno <= use_line:
            if best is None or d.lineno > best.lineno:
                best = d
    return best


def _donated(call: ast.Call) -> Optional[tuple]:
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            return const_tuple(kw.value)    # None if not a static literal
    return ()


def _jit_target(call: ast.Call) -> Optional[str]:
    """Name of the locally-defined function wrapped by this jax.jit call."""
    if call_name(call) != "jax.jit" or not call.args:
        return None
    fn = call.args[0]
    return fn.id if isinstance(fn, ast.Name) else None


@rule("donate-page-pool",
      description="every jax.jit taking a page pool donates it; donated "
                  "bindings are never read after the jitted call",
      paths=("src/repro/runtime/steps.py",))
def donate_page_pool(cache, sf) -> List[Finding]:
    """Check donation at jit sites + def-use of donated args at call sites."""
    out = []
    defs = _function_defs(sf.tree)
    owners = enclosing_functions(sf.tree)

    # pass 1: jit sites — pool params must be in donate_argnums; remember
    # which local names are bound to donating jitted callables
    donating: Dict[str, tuple] = {}     # bound name -> donated indices
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call) or _jit_target(node) is None:
            continue
        target = _resolve_def(defs, _jit_target(node), node.lineno)
        if target is None:
            continue
        params = [a.arg for a in target.args.args]
        donated = _donated(node)
        pool_idx = [i for i, p in enumerate(params) if p in POOL_PARAMS]
        if donated is not None:
            for i in pool_idx:
                if i not in donated:
                    out.append(Finding(
                        "donate-page-pool", sf.rel, node.lineno,
                        f"jax.jit({target.name}) takes the pool parameter "
                        f"'{params[i]}' (arg {i}) but does not donate it — "
                        f"add it to donate_argnums (pools are the dominant "
                        f"serving tensors)"))
        # name this jit is assigned to, for the def-use pass
        owner_stmt = node
        parent = owners.get(node)
        for cand in ast.walk(parent if parent is not None else sf.tree):
            if (isinstance(cand, ast.Assign) and cand.value is node
                    and len(cand.targets) == 1
                    and isinstance(cand.targets[0], ast.Name)):
                donating[cand.targets[0].id] = donated or ()

    # pass 2: def-use — donated arg bindings are dead after the call.
    # Nodes are grouped by their *innermost* enclosing function so a call
    # inside a nested def is not double-walked via its parent.
    for fn in defs:
        # collect (call_line, var_name) for donated positions
        events: List[Tuple[int, str]] = []
        for node in ast.walk(fn):
            if owners.get(node) is not fn or not isinstance(node, ast.Call):
                continue
            callee = node.func
            if not (isinstance(callee, ast.Name) and callee.id in donating):
                continue
            for i in donating[callee.id] or ():
                if i < len(node.args) and isinstance(node.args[i], ast.Name):
                    events.append((node.lineno, node.args[i].id))
        if not events:
            continue
        assigns = []    # (line, name) rebinds
        loads = []      # (line, name) reads
        for node in ast.walk(fn):
            if owners.get(node) is not fn or not isinstance(node, ast.Name):
                continue
            if isinstance(node.ctx, ast.Store):
                assigns.append((node.lineno, node.id))
            elif isinstance(node.ctx, ast.Load):
                loads.append((node.lineno, node.id))
        for call_line, var in events:
            rebinds = [ln for ln, nm in assigns if nm == var and ln >= call_line]
            next_rebind = min(rebinds) if rebinds else None
            for ln, nm in loads:
                if nm != var or ln <= call_line:
                    continue
                if next_rebind is not None and ln > next_rebind:
                    continue
                out.append(Finding(
                    "donate-page-pool", sf.rel, ln,
                    f"'{var}' read after being donated to a jitted call on "
                    f"line {call_line} — the buffer is deleted; rebind or "
                    f"reorder"))
    return out
