"""Kernel-layer contracts: the fold, the launch helper, f32 state, NEG_INF.

Four rules guarding the invariants the attention kernels were burned by:

* ``no-inline-softmax-fold`` — the online-softmax fold exists exactly twice
  (``kernels/common.py::online_fold`` in-kernel, ``core/online_softmax.py``
  as pure arrays). The seed shipped three near-copies, one silently missing
  the fully-masked-row ``m == NEG_INF`` guard; any new ``jnp.exp(s - …)``
  must route through the shared fold or carry a justified suppression.
* ``mosaic-kwargs-launch`` — every ``pl.pallas_call`` takes its compiler
  params via ``common.mosaic_kwargs``; inline ``CompilerParams`` boilerplate
  is how the interpret-mode switch drifted between wrappers pre-PR 5.
* ``f32-accumulators`` — kernel scratch holding ``(acc, m, l)`` state stays
  ``float32``; a bf16 scratch or an accumulator downcast loses exactly the
  bits the online rescale algebra (paper Eq. 2/3) depends on.
* ``shared-mask-constant`` — ``NEG_INF`` is defined once in
  ``core/online_softmax.py`` (a large *finite* negative so ``exp`` stays
  NaN-free on every path); local ``-1e9``/``-inf`` variants break the
  ``m == NEG_INF`` sentinel comparisons that gate fully-masked rows.
"""

from __future__ import annotations

import ast
from typing import List

from tools.analysis.core import (Finding, call_name, dotted,
                                 enclosing_functions, rule)

#: function defs allowed to spell the fold inline: the two canonical homes
FOLD_HOMES = {
    ("src/repro/kernels/common.py", "online_fold"),
    ("src/repro/core/online_softmax.py", None),     # whole module exempt
}

#: names an exp(<name> - …) is treated as a score tile (the fold's input)
SCORE_NAMES = {"s", "scores"}


@rule("no-inline-softmax-fold",
      description="in-kernel exp(s - m) folds must route through "
                  "kernels/common.py::online_fold (the masked-row-guard "
                  "bug class)",
      paths=("src/repro/kernels/*.py", "src/repro/core/*.py"))
def no_inline_softmax_fold(cache, sf) -> List[Finding]:
    """Flag ``jnp.exp(s - …)`` outside the two canonical fold homes."""
    if (sf.rel, None) in FOLD_HOMES:
        return []
    owners = enclosing_functions(sf.tree)
    out = []
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call)
                and call_name(node) in ("jnp.exp", "np.exp")
                and node.args):
            continue
        arg = node.args[0]
        if not (isinstance(arg, ast.BinOp) and isinstance(arg.op, ast.Sub)):
            continue
        left = arg.left
        if not (isinstance(left, ast.Name) and left.id in SCORE_NAMES):
            continue
        fn = owners.get(node)
        if fn is not None and (sf.rel, fn.name) in FOLD_HOMES:
            continue
        out.append(Finding(
            "no-inline-softmax-fold", sf.rel, node.lineno,
            "exp(s - …) outside online_fold/online_softmax — route the "
            "fold through kernels/common.py::online_fold (it carries the "
            "fully-masked-row m == NEG_INF guard)"))
    return out


def _mosaic_bound_names(tree: ast.Module) -> set:
    """Names anywhere in the module bound to a ``mosaic_kwargs(...)`` call."""
    names = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)
                and (call_name(node.value) or "").endswith("mosaic_kwargs")):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    names.add(tgt.id)
    return names


@rule("mosaic-kwargs-launch",
      description="every pl.pallas_call takes compiler params via "
                  "common.mosaic_kwargs, never inline",
      paths=("src/repro/**/*.py",))
def mosaic_kwargs_launch(cache, sf) -> List[Finding]:
    """Flag pallas_call with inline compiler_params / without the helper."""
    bound = _mosaic_bound_names(sf.tree)
    out = []
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call)
                and (call_name(node) or "").endswith("pallas_call")):
            continue
        has_helper = False
        for kw in node.keywords:
            if kw.arg == "compiler_params":
                out.append(Finding(
                    "mosaic-kwargs-launch", sf.rel, node.lineno,
                    "inline compiler_params= on pallas_call — use "
                    "kernels/common.py::mosaic_kwargs"))
            if kw.arg is None:      # **splat
                v = kw.value
                if (isinstance(v, ast.Call)
                        and (call_name(v) or "").endswith("mosaic_kwargs")):
                    has_helper = True
                elif isinstance(v, ast.Name) and v.id in bound:
                    has_helper = True
        if not has_helper:
            out.append(Finding(
                "mosaic-kwargs-launch", sf.rel, node.lineno,
                "pallas_call without **mosaic_kwargs(...) — the launch "
                "boilerplate (CompilerParams/interpret switch) must come "
                "from kernels/common.py::mosaic_kwargs"))
    return out


#: scratch-state reference names whose stores must stay f32
ACC_REFS = {"acc_ref", "m_ref", "l_ref"}
DOWNCAST_DTYPES = {"jnp.float16", "jnp.bfloat16", "jnp.int8", "jnp.float8_e4m3fn",
                   "jnp.float8_e5m2", "np.float16"}


@rule("f32-accumulators",
      description="kernel scratch and (acc, m, l) accumulator state stay "
                  "float32 — no downcasts",
      paths=("src/repro/kernels/*.py",))
def f32_accumulators(cache, sf) -> List[Finding]:
    """Flag non-f32 VMEM scratch and sub-f32 astype on (acc, m, l) stores."""
    out = []
    for node in ast.walk(sf.tree):
        # pltpu.VMEM(shape, dtype): scratch carrying the online state is f32
        if (isinstance(node, ast.Call)
                and (call_name(node) or "").endswith("VMEM")
                and len(node.args) >= 2):
            dt = dotted(node.args[1])
            if dt is not None and dt not in ("jnp.float32", "np.float32"):
                out.append(Finding(
                    "f32-accumulators", sf.rel, node.lineno,
                    f"VMEM scratch declared {dt} — online-softmax state "
                    f"scratch must be jnp.float32"))
        # acc_ref[...] = <expr containing .astype(<sub-f32>)>
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if (isinstance(tgt, ast.Subscript)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id in ACC_REFS):
                    for sub in ast.walk(node.value):
                        if (isinstance(sub, ast.Call)
                                and isinstance(sub.func, ast.Attribute)
                                and sub.func.attr == "astype"
                                and sub.args
                                and dotted(sub.args[0]) in DOWNCAST_DTYPES):
                            out.append(Finding(
                                "f32-accumulators", sf.rel, sub.lineno,
                                f"{tgt.value.id} store downcasts via "
                                f".astype({dotted(sub.args[0])}) — the "
                                f"(acc, m, l) state must stay float32"))
    return out


#: |value| at or beyond this is a masking constant, not arithmetic
MASK_MAGNITUDE = 1e9


@rule("shared-mask-constant",
      description="no local -1e9/-inf style mask constants — import "
                  "NEG_INF from core.online_softmax",
      paths=("src/**/*.py", "tools/**/*.py"))
def shared_mask_constant(cache, sf) -> List[Finding]:
    """Flag large-negative literals and -inf spellings outside the source."""
    if sf.rel == "src/repro/core/online_softmax.py":
        return []       # the one definition site
    out = []
    for node in ast.walk(sf.tree):
        bad = None
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            opnd = node.operand
            if (isinstance(opnd, ast.Constant)
                    and isinstance(opnd.value, (int, float))
                    and abs(opnd.value) >= MASK_MAGNITUDE):
                bad = f"-{opnd.value:g}"
            elif dotted(opnd) in ("jnp.inf", "np.inf", "math.inf"):
                bad = f"-{dotted(opnd)}"
        elif (isinstance(node, ast.Call)
              and dotted(node.func) in ("float", "jnp.float32", "np.float32")
              and node.args and isinstance(node.args[0], ast.Constant)
              and str(node.args[0].value).lstrip().startswith("-inf")):
            bad = "float('-inf')"
        elif dotted(node) in ("np.NINF", "numpy.NINF"):
            bad = dotted(node)
        if bad is not None:
            out.append(Finding(
                "shared-mask-constant", sf.rel, node.lineno,
                f"local mask constant {bad} — import NEG_INF from "
                f"repro.core.online_softmax (finite sentinel the masked-row "
                f"guards compare against)"))
    return out
