"""Config-zoo contract: the FSDP profile annotation never acts alone.

The PR 3 seed bug: ``default_rules`` took the full ZeRO-3 profile from
``sharding_profile="fsdp"`` *alone*, FSDP-sharding embed/vocab on configs
(granite, hubert) that annotate the profile as a scale note but expect
TP-SP. The gate now requires ``fsdp=True`` too — so a config that sets the
profile without the flag is either relying on the old buggy behaviour or
annotating intentionally, and must say which (fix it, or suppress with the
justification).
"""

from __future__ import annotations

import ast
from typing import List

from tools.analysis.core import Finding, rule


def _kw(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


@rule("fsdp-profile-gate",
      description="sharding_profile='fsdp' without fsdp=True is flagged "
                  "(the PR 3 annotation-alone bug class)",
      paths=("src/repro/configs/*.py",))
def fsdp_profile_gate(cache, sf) -> List[Finding]:
    """Flag any call setting the fsdp profile without the opt-in flag."""
    out = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        prof = _kw(node, "sharding_profile")
        if not (isinstance(prof, ast.Constant) and prof.value == "fsdp"):
            continue
        flag = _kw(node, "fsdp")
        if isinstance(flag, ast.Constant) and flag.value is True:
            continue
        out.append(Finding(
            "fsdp-profile-gate", sf.rel, prof.lineno,
            "sharding_profile='fsdp' without fsdp=True — the rule engine "
            "keeps TP-SP (profile gate requires both flags); set fsdp=True "
            "or suppress with the intent"))
    return out
