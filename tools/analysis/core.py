"""sparklint core: shared AST cache, findings, suppressions, rule registry.

The checker exists because every expensive bug this repo has shipped was a
*contract* violation invisible to pytest until the right composition hit it
(a kernel fold missing the fully-masked-row guard, the FSDP gate firing on
annotation alone, double-frees aliasing two sequences' KV). Each rule in
``tools/analysis/rules/`` machine-checks one such invariant; this module is
the substrate they share:

* :class:`AstCache` — parse each file once per run, shared by every rule
  (and by ``tools/check_docs.py``, which runs its AST checks on the same
  cache — one analysis substrate for the repo);
* :class:`Finding` — one violation: file, line, rule id, message;
* suppressions — ``# sparklint: disable=<rule>[,<rule>] -- <justification>``
  on the offending line (or alone on the line above it). The justification
  after ``--`` is mandatory: an unjustified disable is itself reported under
  the ``suppression-justification`` rule, so exceptions stay documented;
* :func:`rule` / :func:`run` — registry and driver. A rule declares the
  repo-relative globs it applies to, so the same rule runs unchanged on the
  real tree and on the fixture trees in ``tests/test_sparklint.py``.
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Set

SUPPRESS_RE = re.compile(
    r"#\s*sparklint:\s*disable=([A-Za-z0-9_,\- ]+?)\s*(?:--\s*(\S.*))?$")

#: rule id under which unjustified suppressions are reported
JUSTIFICATION_RULE = "suppression-justification"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation: where (file:line), what (rule id), why (message)."""
    rule: str
    path: str      # repo-relative posix path
    line: int
    message: str

    def text(self) -> str:
        """The one-line ``path:line: [rule] message`` form used by the CLI."""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_json(self) -> Dict:
        """JSON-object form (stable schema: rule/path/line/message)."""
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}


class SourceFile:
    """One parsed source file: text, lines, AST, and its suppression table.

    ``suppressions`` maps line number → set of rule ids disabled on that
    line; a disable comment on a line of its own covers the next line (the
    statement it annotates). ``unjustified`` lists the lines whose disable
    comment is missing the mandatory ``-- <why>`` tail.
    """

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree: Optional[ast.Module] = ast.parse(self.text)
        except SyntaxError as e:
            self.tree = None
            self.parse_error = e
        self.suppressions: Dict[int, Set[str]] = {}
        self.unjustified: List[int] = []
        self._scan_suppressions()

    def _scan_suppressions(self) -> None:
        for lineno, line in enumerate(self.lines, 1):
            m = SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            if not m.group(2):
                self.unjustified.append(lineno)
            target = lineno
            if line.split("#", 1)[0].strip() == "":
                target = lineno + 1     # comment-only line covers the next
            self.suppressions.setdefault(target, set()).update(rules)

    def suppressed(self, rule_id: str, line: int) -> bool:
        """True if ``rule_id`` is disabled on ``line`` (or globally-per-line)."""
        active = self.suppressions.get(line, ())
        return rule_id in active or "all" in active


class AstCache:
    """Parse-once cache over a source tree root; rules and check_docs share it."""

    def __init__(self, root: Path):
        self.root = Path(root).resolve()
        self._files: Dict[str, SourceFile] = {}

    def get(self, path) -> SourceFile:
        """The cached :class:`SourceFile` for ``path`` (absolute or relative)."""
        p = Path(path)
        if not p.is_absolute():
            p = self.root / p
        rel = p.resolve().relative_to(self.root).as_posix()
        if rel not in self._files:
            self._files[rel] = SourceFile(p, rel)
        return self._files[rel]

    def iter_python(self, *dirs: str) -> Iterable[SourceFile]:
        """Every ``*.py`` under the given root-relative dirs, sorted, cached."""
        for d in dirs:
            base = self.root / d
            if not base.is_dir():
                continue
            for py in sorted(base.rglob("*.py")):
                if "__pycache__" in py.parts:
                    continue
                yield self.get(py)

    def matching(self, patterns: Iterable[str],
                 search_dirs: Iterable[str]) -> Iterable[SourceFile]:
        """Files under ``search_dirs`` whose relpath matches any glob."""
        for sf in self.iter_python(*search_dirs):
            if any(fnmatch.fnmatch(sf.rel, pat) for pat in patterns):
                yield sf


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered rule: id, one-line contract, target globs, check function."""
    id: str
    description: str
    paths: tuple
    check: Callable          # (AstCache, SourceFile) -> List[Finding]


RULES: Dict[str, Rule] = {}


def rule(rule_id: str, *, description: str, paths: Iterable[str]):
    """Register a per-file rule. ``check(cache, sf)`` returns raw findings;
    the driver applies suppressions and ordering."""
    def deco(fn):
        RULES[rule_id] = Rule(rule_id, description, tuple(paths), fn)
        return fn
    return deco


# directories a default run scans (rule globs narrow further); tests/ is
# read by the oracle-coverage rule through the cache but not scanned itself
DEFAULT_DIRS = ("src", "tools")


def run(root, *, dirs: Iterable[str] = DEFAULT_DIRS,
        rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Run every (selected) rule over ``root``; returns ordered findings.

    Suppressions are applied here: a finding whose line carries a matching
    ``disable`` is dropped, and every disable missing its justification is
    reported once under ``suppression-justification``.
    """
    from tools.analysis import rules as _rules  # noqa: F401  (registers)
    cache = AstCache(Path(root))
    selected = [RULES[r] for r in rules] if rules else list(RULES.values())
    findings: List[Finding] = []
    seen_files: Dict[str, SourceFile] = {}
    for rl in selected:
        for sf in cache.matching(rl.paths, dirs):
            seen_files[sf.rel] = sf
            if sf.parse_error is not None:
                findings.append(Finding(
                    rl.id, sf.rel, sf.parse_error.lineno or 1,
                    f"unparsable file: {sf.parse_error.msg}"))
                continue
            for f in rl.check(cache, sf):
                if not sf.suppressed(f.rule, f.line):
                    findings.append(f)
    for sf in seen_files.values():
        for lineno in sf.unjustified:
            findings.append(Finding(
                JUSTIFICATION_RULE, sf.rel, lineno,
                "sparklint disable without a '-- <justification>' tail"))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


# ---- small AST helpers shared by the rule modules ----

def dotted(node: ast.AST) -> Optional[str]:
    """'jnp.float32' for Attribute/Name chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    """Dotted name of a call's callee ('pl.pallas_call'), else None."""
    return dotted(call.func)


def const_tuple(node: ast.AST) -> Optional[tuple]:
    """Statically evaluate a tuple/int literal (donate_argnums), else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, ast.Tuple):
        vals = []
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, int)):
                return None
            vals.append(elt.value)
        return tuple(vals)
    return None


def enclosing_functions(tree: ast.Module) -> Dict[ast.AST, Optional[ast.AST]]:
    """Map each node to its innermost enclosing FunctionDef (None = module).

    A FunctionDef maps to the function *containing* it, so nested helpers
    attribute to their parent and a def's own body attributes to the def.
    """
    owner: Dict[ast.AST, Optional[ast.AST]] = {tree: None}

    def visit(node, fn):
        for child in ast.iter_child_nodes(node):
            owner[child] = fn
            child_fn = child if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef)) else fn
            visit(child, child_fn)

    visit(tree, None)
    return owner
