"""sparklint — AST-based contract checker for this repo's hard-won invariants.

Run with ``python -m tools.analysis`` (CI's required ``lint`` job). Every
rule encodes a contract a past bug taught us (docs/analysis.md maps each
rule to its motivating incident); violations exit non-zero. Intentional
exceptions carry ``# sparklint: disable=<rule> -- <justification>`` inline.

Programmatic use::

    from tools.analysis import run
    findings = run("/path/to/repo")        # list[Finding], suppressions applied
"""

from tools.analysis.core import (AstCache, Finding, JUSTIFICATION_RULE,  # noqa: F401
                                 RULES, rule, run)
