"""sparklint CLI: ``python -m tools.analysis [--json] [--rule ID ...] [ROOT]``.

Text mode prints one ``path:line: [rule] message`` per finding plus a
summary; ``--json`` emits ``{"findings": [...], "count": N}`` on stdout for
tooling. Exit status: 0 clean, 1 findings, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.analysis.core import DEFAULT_DIRS, RULES, run


def main(argv=None) -> int:
    """Parse args, run the registered rules, print findings, return status."""
    from tools.analysis import rules as _rules  # noqa: F401  (registers)
    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="sparklint: repo-contract static checks")
    ap.add_argument("root", nargs="?", default=None,
                    help="repo root to scan (default: this repo)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--rule", action="append", dest="rules", metavar="ID",
                    help="run only this rule id (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="list registered rules and exit")
    args = ap.parse_args(argv)

    if args.list:
        for rid, rl in sorted(RULES.items()):
            print(f"{rid}: {rl.description}")
        return 0

    root = Path(args.root) if args.root else \
        Path(__file__).resolve().parent.parent.parent
    unknown = [r for r in (args.rules or ()) if r not in RULES]
    if unknown:
        print(f"unknown rule id(s): {', '.join(unknown)}", file=sys.stderr)
        return 2

    findings = run(root, dirs=DEFAULT_DIRS, rules=args.rules)
    if args.as_json:
        print(json.dumps({"findings": [f.to_json() for f in findings],
                          "count": len(findings)}, indent=2))
    else:
        for f in findings:
            print(f.text())
        print(f"sparklint: {'FAIL' if findings else 'ok'} "
              f"({len(findings)} finding(s), {len(RULES)} rule(s))")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
