"""Serving launcher: batched prefill + autoregressive decode.

CPU-runnable smoke examples:
  PYTHONPATH=src python -m repro.launch.serve --arch granite_3_2b --smoke \\
      --batch 4 --prompt-len 64 --gen 32

Paged continuous batching (block-table cache, ragged synthetic requests):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \\
      --paged --requests 8 --page-size 16 --gen 32

Lazy admission (prompt-only page reservation, one-page decode growth,
youngest-row preemption + re-prefill when the pool runs dry — higher page
utilization than the default eager full-budget reservation):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \\
      --paged --lazy --requests 8 --gen 32

Prefix caching + chunked prefill (every synthetic request opens with a
common system prompt; matched page-aligned blocks alias already-prefilled
pages and skip their prefill compute, --prefill-chunk interleaves long
prompts with decode steps):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \\
      --paged --share-prefix --prefill-chunk 32 --requests 8 --gen 32

Speculative decoding (prompt-lookup drafts verified k+1 tokens at a time;
repetitive synthetic prompts make the n-gram drafter actually land):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \\
      --paged --speculate 4 --requests 8 --gen 32

Distributed paged serving (page pool sharded over the mesh's model axis;
needs that many devices, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=2):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \\
      --paged --mesh 2 --requests 8 --gen 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_mesh
from repro.launch.train import parse_mesh
from repro.runtime.steps import make_serve_steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas_interpret", "naive"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="paged-KV continuous batching (ragged requests)")
    ap.add_argument("--requests", type=int, default=8,
                    help="--paged: synthetic requests to serve")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="--paged: concurrent decode slots")
    ap.add_argument("--lazy", action="store_true",
                    help="--paged: lazy page growth + preemption/re-prefill "
                         "instead of eager full-budget reservation")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="--paged: override the page-pool size (0 = auto; "
                         "shrink it to watch --lazy preempt)")
    ap.add_argument("--share-prefix", action="store_true",
                    help="--paged: content-addressed prefix caching + "
                         "copy-on-write pages (requests then share a common "
                         "system prompt so the cache has something to hit)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="--paged: max prompt tokens prefilled per engine "
                         "iteration (0 = whole prompts at once)")
    ap.add_argument("--speculate", type=int, default=0,
                    help="--paged: speculative decoding — verify up to this "
                         "many prompt-lookup draft tokens per decode step "
                         "(0 = off); token-identical to plain greedy decode")
    ap.add_argument("--num-splits", type=int, default=0,
                    help="split-KV decode: parallel KV partitions per "
                         "(batch, kv-head) row (0 = 1, or autotuned with "
                         "--autotune)")
    ap.add_argument("--deadline-ms", type=float, default=0,
                    help="--paged: per-request wall-clock deadline in "
                         "milliseconds; expired requests terminate with a "
                         "TIMEOUT outcome (0 = no deadline)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="--paged: bounded admission queue — submissions "
                         "past this many waiting requests shed with a SHED "
                         "outcome (0 = unbounded)")
    ap.add_argument("--fault-plan", type=int, default=-1,
                    help="--paged: seed a replayable chaos FaultPlan "
                         "(serving/faults.py) injecting pool exhaustion, "
                         "preemption storms, freed-page poison, NaN logits "
                         "and cancellations (-1 = off)")
    ap.add_argument("--autotune", action="store_true",
                    help="pick --num-splits from the perf/autotune.py cost "
                         "model (persistent cache; explicit --num-splits "
                         "wins)")
    args = ap.parse_args(argv)

    cfg = (configs.smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    mesh = parse_mesh(args.mesh)

    if args.paged:
        return serve_paged(cfg, args, mesh)

    max_len = args.prompt_len + args.gen
    num_splits, block_kv = args.num_splits or 1, 128   # Ctx.block_kv default
    if args.autotune and not args.num_splits:
        from repro.perf.autotune import DecodeShape, plan_decode_persistent
        shape = DecodeShape(batch=args.batch, hkv=cfg.num_kv_heads,
                            group=cfg.num_heads // cfg.num_kv_heads,
                            kv_len=max_len, head_dim=cfg.head_dim,
                            dtype_bytes=jnp.dtype(cfg.dtype).itemsize)
        plan = plan_decode_persistent(shape)
        num_splits, block_kv = plan.num_splits, plan.block_kv
        print(f"autotune: num_splits={plan.num_splits} "
              f"block_kv={plan.block_kv} ({plan.source}, "
              f"predicted {plan.time_s*1e6:.1f}us/layer)")
    arts = make_serve_steps(cfg, mesh=mesh, impl=args.impl, max_len=max_len,
                            batch=args.batch, num_splits=num_splits,
                            block_kv=block_kv,
                            xla_chunk=min(1024, args.prompt_len))

    from repro.models import lm
    key = jax.random.PRNGKey(args.seed)
    params, _ = lm.init_params(
        cfg, key, vocab_pad_to=mesh.shape.get("model", 1) if mesh else 1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    caches = arts.cache_init_fn()
    t0 = time.perf_counter()
    logits, caches = arts.prefill_fn(params, prompt, None, caches)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, caches = arts.decode_fn(params, tok, caches,
                                        jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f}ms; "
          f"decode: {args.gen-1} steps in {t_decode*1e3:.1f}ms "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("generated (first row):", gen[0][:16])


def serve_paged(cfg, args, mesh=None):
    """Continuous batching over ragged synthetic requests (paged KV cache)."""
    from repro.serving import FaultPlan, PagedCacheConfig, ServingEngine

    from repro.models import lm
    key = jax.random.PRNGKey(args.seed)
    n_shards = int(mesh.shape.get("model", 1)) if mesh is not None else 1
    params, _ = lm.init_params(cfg, key, vocab_pad_to=n_shards)
    rs = np.random.RandomState(args.seed)
    budget = args.prompt_len + args.gen
    # pool sized so roughly half the requests fit at once — the scheduler
    # has to actually evict/admit, which is the scenario being demoed —
    # then padded so the page-aligned shard split divides evenly
    num_pages = args.num_pages or (n_shards + max(2, args.requests // 2) * (
        -(-budget // args.page_size) + 1))
    num_pages = -(-num_pages // n_shards) * n_shards
    pcfg = PagedCacheConfig(
        page_size=args.page_size,
        max_batch=args.max_batch,
        max_pages_per_seq=-(-budget // args.page_size) + 1,
        num_pages=num_pages,
        num_shards=n_shards)
    # lazy mode: a preempted row re-prefills prompt+generated, so the prefill
    # row must hold a full budget
    prefill_len = max(args.prompt_len, args.page_size)
    if args.lazy:
        prefill_len = max(prefill_len, budget)
    plan = (FaultPlan(seed=args.fault_plan)
            if args.fault_plan >= 0 else None)
    eng = ServingEngine(cfg, pcfg, params, impl=args.impl, mesh=mesh,
                        prefill_len=prefill_len, lazy=args.lazy,
                        num_splits=args.num_splits or None,
                        autotune=args.autotune,
                        share_prefix=args.share_prefix,
                        prefill_chunk=args.prefill_chunk or None,
                        speculate_k=args.speculate or None,
                        deadline_ms=args.deadline_ms or None,
                        max_queue=args.max_queue or None,
                        fault_plan=plan)
    if plan is not None:
        print(f"fault plan (seed {plan.seed}): "
              + " ".join(f"{e.kind}@{e.step}" for e in plan.events))
    if args.autotune or args.num_splits:
        print(f"decode num_splits: {eng.num_splits}"
              + (" (autotuned)" if args.autotune and not args.num_splits
                 else ""))
    # with sharing on, every request opens with one common system prompt
    # (half the nominal prompt length) so the prefix cache has repeats to hit
    system = (rs.randint(0, cfg.vocab_size, size=max(1, args.prompt_len // 2))
              if args.share_prefix else np.zeros(0, np.int64))
    reqs = []
    for _ in range(args.requests):  # ragged: 25%..100% of the nominal lengths
        plen = int(rs.randint(max(1, args.prompt_len // 4), args.prompt_len + 1))
        gen = int(rs.randint(max(1, args.gen // 4), args.gen + 1))
        if args.speculate:
            # a tiled motif gives the prompt-lookup drafter n-gram repeats
            # to match against (uniform-random prompts rarely draft at all)
            motif = rs.randint(0, cfg.vocab_size, size=8)
            tail = np.tile(motif, -(-plen // 8))[:plen]
        else:
            tail = rs.randint(0, cfg.vocab_size, size=plen)
        reqs.append((np.concatenate([system, tail])[:pcfg.max_seq_len
                                                    - args.gen - 1], gen))
    out, stats = eng.run(reqs)
    mode = "lazy" if args.lazy else "eager"
    print(f"served {len(out)} requests ({stats['generated_tokens']:.0f} tokens) "
          f"in {stats['wall_s']*1e3:.1f}ms: {stats['tokens_per_s']:.1f} tok/s, "
          f"{stats['decode_steps']:.0f} decode steps, "
          f"{mode} page utilization {stats['mean_utilization']:.1%}")
    print(f"scheduler: {stats['preemptions']:.0f} preemptions, "
          f"{stats['pages_grown']:.0f} pages grown lazily, "
          f"{stats['pages_reclaimed']:.0f} out-of-window pages reclaimed")
    if args.share_prefix or args.prefill_chunk:
        print(f"prefix/chunking: {stats['prefill_tokens']:.0f} prompt tokens "
              f"prefilled, {stats['prefill_tokens_skipped']:.0f} skipped via "
              f"prefix hits, {stats['pages_shared']:.0f} page aliases, "
              f"{stats['cow_copies']:.0f} copy-on-writes")
    if args.speculate:
        print(f"speculation: {stats['drafted_tokens']:.0f} tokens drafted, "
              f"{stats['accepted_tokens']:.0f} accepted "
              f"({stats['acceptance_rate']:.1%}), "
              f"{stats['generated_tokens'] / max(stats['decode_steps'], 1):.2f} "
              f"tokens/verify step")
    counts = stats["outcomes"]
    print("outcomes: " + " ".join(f"{k}={v}" for k, v in counts.items())
          + (f" (watchdog_fires={stats['watchdog_fires']:.0f})"
             if stats["watchdog_fires"] else ""))
    if 0 in out:
        print("generated (request 0):", out[0][:16])
    else:  # request 0 cancelled/timed out/shed/failed under a fault plan
        print("request 0 did not complete:",
              eng.results[0].outcome.value, "—", eng.results[0].reason)


if __name__ == "__main__":
    main()
