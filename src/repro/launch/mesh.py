"""Production mesh builders.

(16, 16) single pod = 256 chips; (2, 16, 16) = 2 pods / 512 chips. Functions,
not module constants — importing this never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic re-scaling). Uses the first
    prod(shape) devices so a 512-device dry-run backend can build both the
    single-pod (256-chip) and multi-pod (512-chip) meshes."""
    import math
    import numpy as np
    n = math.prod(shape)
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    if hasattr(jax.sharding, "AxisType"):
        # newer jax: axes must be explicitly Auto for with_sharding_constraint
        return jax.sharding.Mesh(
            devices, tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.sharding.Mesh(devices, tuple(axes))  # jax<=0.4: Auto implied
