import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements in this module — jax locks
the device count at first init, and the production meshes need 512 host
placeholder devices. Nothing here allocates a real tensor: inputs are
ShapeDtypeStructs, outputs are compile-time artifacts (memory analysis, cost
analysis, collective schedule) written to artifacts/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite_3_2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--skip-done]
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import SHAPES, cells, get_config
from repro.distributed.sharding import uses_fsdp_profile
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.perf import collective_stats, roofline
from repro.perf.memory_model import storage_for, traffic_for
from repro.runtime.steps import make_serve_steps, make_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _batch_sds(cfg, shape, rules, mesh):
    b, s = shape.global_batch, shape.seq_len
    def sh(axes, shp):
        return NamedSharding(mesh, rules.spec_for(axes, shp))
    batch = {"labels": _sds((b, s), jnp.int32,
                            sh(("batch", None), (b, s)))}
    if cfg.frontend is not None:
        batch["embeds"] = _sds((b, s, lm.FRONTEND_DIM), jnp.bfloat16,
                               sh(("batch", None, None),
                                  (b, s, lm.FRONTEND_DIM)))
    else:
        batch["tokens"] = _sds((b, s), jnp.int32, sh(("batch", None), (b, s)))
    return batch


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
               xla_chunk: int = 1024, microbatch=None,
               variant: str = "scan", cfg_override=None,
               decode_write: str = "dus"):
    """Returns (lowered, compiled, meta) for one cell.

    variant="scan"   — production lowering (lax.scan over layers + remat):
                       memory analysis is authoritative; XLA cost analysis
                       undercounts loop bodies.
    variant="unroll" — layer stack and attention chunk loops unrolled:
                       FLOPs/bytes/collective counts are authoritative; the
                       un-remat'd memory analysis is not.
    """
    import dataclasses as _dc
    cfg = cfg_override or get_config(arch_name)
    xla_unroll = False
    if variant == "unroll":
        cfg = _dc.replace(cfg, scan_layers=False, remat=False)
        xla_unroll = True
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    vocab_pad = mesh.shape.get("model", 1)

    if shape.kind == "train":
        arts = make_train_step(cfg, mesh=mesh, impl="xla", donate=True,
                               xla_chunk=xla_chunk, microbatch=microbatch,
                               xla_unroll=xla_unroll)
        params_sds, specs = lm.abstract_params(cfg, vocab_pad_to=vocab_pad)
        p_shard = arts.shardings["params"]
        o_shard = arts.shardings["opt"]
        params_in = jax.tree.map(lambda sds, sh: _sds(sds.shape, sds.dtype, sh),
                                 params_sds, p_shard)
        from repro.optim import AdamWConfig, adamw_init
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, AdamWConfig()),
                                 params_sds)
        opt_in = jax.tree.map(lambda sds, sh: _sds(sds.shape, sds.dtype, sh),
                              opt_sds, o_shard)
        batch_in = _batch_sds(cfg, shape, arts.rules, mesh)
        step_in = _sds((), jnp.int32, NamedSharding(mesh, P()))
        lowered = arts.step_fn.lower(params_in, opt_in, batch_in, step_in)
        rules = arts.rules
    else:
        arts = make_serve_steps(cfg, mesh=mesh, impl="xla",
                                max_len=shape.seq_len,
                                batch=shape.global_batch, xla_chunk=xla_chunk,
                                xla_unroll=xla_unroll,
                                decode_write=decode_write)
        rules = arts.rules if shape.kind == "prefill" else arts.rules_decode
        params_sds, specs = lm.abstract_params(cfg, vocab_pad_to=vocab_pad)
        p_shard = rules.tree_shardings(params_sds, specs)
        params_in = jax.tree.map(lambda sds, sh: _sds(sds.shape, sds.dtype, sh),
                                 params_sds, p_shard)
        if shape.kind == "prefill":
            batch = _batch_sds(cfg, shape, rules, mesh)
            caches_sds = jax.eval_shape(arts.cache_init_fn)
            cache_in = jax.tree.map(
                lambda s_: _sds(s_.shape, s_.dtype), caches_sds)
            lowered = arts.prefill_fn.lower(
                params_in, batch.get("tokens"), batch.get("embeds"), cache_in)
        else:  # decode
            caches_sds = jax.eval_shape(arts.cache_init_fn)

            def cache_shard(path_leaf):
                return None
            cache_in = jax.tree.map(
                lambda s_: _sds(s_.shape, s_.dtype), caches_sds)
            # KV cache shardings via rules (k/v leaves are rank-5 stacked)
            def attach(sds):
                if sds.ndim == 5:    # [n_super, B, Hkv, S, D]
                    sh = NamedSharding(mesh, rules.spec_for(
                        ("layers", "batch", "kv_heads", "kv_cache_seq",
                         "head_dim"), sds.shape))
                    return _sds(sds.shape, sds.dtype, sh)
                if sds.ndim >= 2:    # recurrent states [n_super, B, ...]
                    axes = ("layers", "batch") + (None,) * (sds.ndim - 2)
                    sh = NamedSharding(mesh, rules.spec_for(axes, sds.shape))
                    return _sds(sds.shape, sds.dtype, sh)
                return _sds(sds.shape, sds.dtype)
            cache_in = jax.tree.map(attach, cache_in)
            tok_in = _sds((shape.global_batch,), jnp.int32,
                          NamedSharding(mesh, rules.spec_for(
                              ("batch",), (shape.global_batch,))))
            pos_in = _sds((), jnp.int32, NamedSharding(mesh, P()))
            lowered = arts.decode_fn.lower(params_in, tok_in, cache_in, pos_in)

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_stats(hlo, default_group=chips)
    step_kind = shape.kind
    if uses_fsdp_profile(cfg):
        # no TP: tokens shard over (data x model); params ZeRO-3 over both
        dp_sh = mesh.shape.get("data", 1) * mesh.shape.get("model", 1)
        tp_sh = 1
        fsdp_on = True
    else:
        dp_sh = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        tp_sh = mesh.shape.get("model", 1)
        fsdp_on = cfg.fsdp
    traffic = traffic_for(cfg, shape, dp=dp_sh, tp=tp_sh, fsdp=fsdp_on)
    storage = storage_for(cfg, shape, dp=dp_sh, tp=tp_sh, fsdp=fsdp_on)
    rf = roofline.build(
        cfg, shape, step_kind=step_kind, chips=chips,
        hlo_flops_per_dev=float(cost.get("flops", 0.0)),
        hlo_bytes_per_dev=float(cost.get("bytes accessed", 0.0)),
        coll_bytes_per_dev=coll.total_bytes,
        mem_bytes_model=traffic.total)

    meta = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "compile_s": compile_s,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
            "hbm_per_chip": roofline.HBM_PER_CHIP,
            "fits": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
                    < roofline.HBM_PER_CHIP,
            "storage_analytic": storage,
            "fits_analytic": storage["total"] < roofline.HBM_PER_CHIP,
        },
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "collectives": {"bytes_by_kind": coll.bytes_by_kind,
                        "count_by_kind": coll.count_by_kind,
                        "total_bytes_per_dev": coll.total_bytes},
        "roofline": rf.as_dict(),
        "sharding_fallbacks": dict(rules.fallbacks),
    }
    return lowered, compiled, meta


def _delta_cost(arch_name, shape_name, *, multi_pod, xla_chunk,
                microbatch=None, cfg_override=None, decode_write="dus"):
    """Two unrolled small-depth compiles → extrapolated full-depth cost."""
    import dataclasses as _dc
    cfg = cfg_override or get_config(arch_name)
    shape = SHAPES[shape_name]
    period = len(cfg.block_pattern)
    n_super, rem = divmod(cfg.num_layers, period)

    def cost_at(n_layers):
        c = _dc.replace(cfg, num_layers=n_layers)
        _, compiled, m = lower_cell(arch_name, shape_name,
                                    multi_pod=multi_pod, xla_chunk=xla_chunk,
                                    microbatch=microbatch, variant="unroll",
                                    cfg_override=c, decode_write=decode_write)
        return m

    m1 = cost_at(period)
    m2 = cost_at(2 * period)

    def extrap(get):
        a, b = get(m1), get(m2)
        per_super = b - a
        if per_super < 0:
            # GSPMD may pick different global layouts at different depths
            # (seen on recurrentgemma: one big AR at L=3, sharded at L=6) —
            # a linear fit would go negative. Scale the deeper measurement
            # by depth instead (conservative: assumes it is all per-layer).
            return b / 2.0 * (n_super + rem / period)
        base = max(0.0, a - per_super)
        return base + per_super * (n_super + rem / period)

    flops = extrap(lambda m: m["cost"].get("flops", 0.0))
    bytes_acc = extrap(lambda m: m["cost"].get("bytes accessed", 0.0))
    coll_total = extrap(
        lambda m: m["collectives"]["total_bytes_per_dev"])
    coll_by_kind = {
        k: extrap(lambda m: m["collectives"]["bytes_by_kind"].get(k, 0.0))
        for k in set(m1["collectives"]["bytes_by_kind"])
        | set(m2["collectives"]["bytes_by_kind"])}
    mesh = make_production_mesh(multi_pod=multi_pod)
    if uses_fsdp_profile(cfg):
        # no TP: tokens shard over (data x model); params ZeRO-3 over both
        dp_sh = mesh.shape.get("data", 1) * mesh.shape.get("model", 1)
        tp_sh = 1
        fsdp_on = True
    else:
        dp_sh = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        tp_sh = mesh.shape.get("model", 1)
        fsdp_on = cfg.fsdp
    traffic = traffic_for(cfg, shape, dp=dp_sh, tp=tp_sh, fsdp=fsdp_on)
    rf = roofline.build(
        cfg, shape, step_kind=shape.kind, chips=mesh.size,
        hlo_flops_per_dev=max(flops, 0.0),
        hlo_bytes_per_dev=max(bytes_acc, 0.0),
        coll_bytes_per_dev=max(coll_total, 0.0),
        mem_bytes_model=traffic.total)
    return {
        "traffic_model": traffic.as_dict(),
        "cost": {"flops": flops, "bytes accessed": bytes_acc,
                 "method": f"delta-extrapolated from unrolled "
                           f"L={period},{2*period} to L={cfg.num_layers}"},
        "collectives": {"bytes_by_kind": coll_by_kind,
                        "count_by_kind": {
                            k: m2["collectives"]["count_by_kind"].get(k, 0)
                            for k in m2["collectives"]["count_by_kind"]},
                        "total_bytes_per_dev": coll_total},
        "roofline": rf.as_dict(),
        "compile_s_unroll": m1["compile_s"] + m2["compile_s"],
    }


def run_cell(arch_name, shape_name, *, multi_pod, save=True, verbose=True,
             xla_chunk=1024, microbatch=None, tag="", cfg_override=None,
             decode_write="dus"):
    cfg = cfg_override or get_config(arch_name)
    runnable, reason = cells(cfg)[shape_name]
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    out_path = os.path.join(ART_DIR,
                            f"{arch_name}__{shape_name}__{mesh_tag}{tag}.json")
    if not runnable:
        meta = {"arch": arch_name, "shape": shape_name, "mesh": mesh_tag,
                "skipped": True, "reason": reason}
        if save:
            os.makedirs(ART_DIR, exist_ok=True)
            with open(out_path, "w") as f:
                json.dump(meta, f, indent=1)
        if verbose:
            print(f"SKIP  {arch_name:22s} {shape_name:12s} {mesh_tag}: {reason}")
        return meta
    try:
        _, _, meta = lower_cell(arch_name, shape_name, multi_pod=multi_pod,
                                xla_chunk=xla_chunk, microbatch=microbatch,
                                variant="scan", cfg_override=cfg_override,
                                decode_write=decode_write)
        if not multi_pod:
            # Cost pass: XLA cost analysis counts scan bodies once, and fully
            # unrolled 60-95 layer models compile too slowly at 256 devices.
            # Instead compile UNROLLED models at L=period and L=2·period and
            # extrapolate linearly — exact for uniform stacks, and the layer
            # collectives/FLOPs/bytes are per-layer-additive by construction.
            meta_cost = _delta_cost(arch_name, shape_name,
                                    multi_pod=multi_pod, xla_chunk=xla_chunk,
                                    microbatch=microbatch,
                                    cfg_override=cfg_override,
                                    decode_write=decode_write)
            meta.update(meta_cost)
        else:
            meta["roofline_note"] = ("multi-pod pass proves sharding/compile; "
                                     "roofline numbers come from the "
                                     "single-pod unrolled cost pass")
    except Exception as e:
        meta = {"arch": arch_name, "shape": shape_name, "mesh": mesh_tag,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-3000:]}
        if save:
            os.makedirs(ART_DIR, exist_ok=True)
            with open(out_path, "w") as f:
                json.dump(meta, f, indent=1)
        if verbose:
            print(f"FAIL  {arch_name:22s} {shape_name:12s} {mesh_tag}: "
                  f"{meta['error'][:120]}")
        return meta
    if tag:
        meta["tag"] = tag
    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(meta, f, indent=1)
    if verbose:
        rf = meta["roofline"]
        m = meta["memory"]
        print(f"OK    {arch_name:22s} {shape_name:12s} {mesh_tag} "
              f"compile={meta['compile_s']:6.1f}s "
              f"mem/dev={m['peak_estimate_bytes']/1e9:6.2f}GB fits={m['fits']} "
              f"bound={rf['bound']:10s} mfu={rf['mfu']*100:5.1f}% "
              f"[C={rf['compute_s']*1e3:.1f}ms M={rf['memory_s']*1e3:.1f}ms "
              f"X={rf['collective_s']*1e3:.1f}ms]", flush=True)
    return meta


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--xla-chunk", type=int, default=1024)
    args = ap.parse_args(argv)

    meshes = [args.multipod]
    if args.both_meshes:
        meshes = [False, True]
    todo = []
    archs = configs.ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                todo.append((a, s, mp))
    ok = fail = skip = 0
    for a, s, mp in todo:
        mesh_tag = "2x16x16" if mp else "16x16"
        out_path = os.path.join(ART_DIR, f"{a}__{s}__{mesh_tag}.json")
        if args.skip_done and os.path.exists(out_path):
            with open(out_path) as f:
                prev = json.load(f)
            if "error" not in prev:
                print(f"CACHED {a} {s} {mesh_tag}")
                ok += 1
                continue
        meta = run_cell(a, s, multi_pod=mp, xla_chunk=args.xla_chunk)
        if meta.get("skipped"):
            skip += 1
        elif "error" in meta:
            fail += 1
        else:
            ok += 1
    print(f"\ndry-run summary: {ok} ok, {skip} family-skips, {fail} failures")
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main())
