"""Training launcher.

Examples:
  # CPU-runnable smoke run (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b --smoke \\
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

  # Production lowering (the dry-run does the compile-only variant):
  PYTHONPATH=src python -m repro.launch.train --arch deepseek_67b \\
      --shape train_4k --mesh 16x16 --impl pallas ...

On a real TPU pod this script is launched once per host (JAX distributed
initialization via JAX_COORDINATOR/megascale env as usual); on this container
it runs single-process.
"""

from __future__ import annotations

import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import DataConfig
from repro.launch.mesh import make_mesh
from repro.optim import AdamWConfig
from repro.runtime.steps import make_train_step
from repro.runtime.trainer import Trainer, TrainerConfig


def parse_mesh(s):
    if not s:
        return None
    dims = [int(x) for x in s.split("x")]
    axes = {1: ("model",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return make_mesh(tuple(dims), axes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--mesh", default="", help="e.g. 16x16 or 2x16x16")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas_interpret", "naive"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--dtype", default="float32", choices=["float32", "bfloat16"])
    args = ap.parse_args(argv)

    cfg = (configs.smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, dtype=getattr(jnp, args.dtype))
    mesh = parse_mesh(args.mesh)
    arts = make_train_step(cfg, mesh=mesh, opt=AdamWConfig(lr=args.lr),
                           impl=args.impl, total_steps=args.steps,
                           warmup_steps=args.warmup,
                           microbatch=args.microbatch,
                           xla_chunk=min(1024, args.seq))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, frontend=cfg.frontend)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    batch_shardings = arts.shardings["batch"] if arts.shardings else None
    trainer = Trainer(arts=arts, data_cfg=data_cfg, tcfg=tcfg,
                      batch_shardings=batch_shardings)
    result = trainer.run(args.steps)
    print(f"done at step {result['stop_step']} "
          f"(preempted={result['preempted']}, "
          f"stragglers={len(result['stragglers'])})")


if __name__ == "__main__":
    main()
