import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver (the recorded perf-iteration log; see docs/architecture.md).

Runs the selected hillclimb cells with one optimization applied at a time,
writes tagged artifacts next to the baselines, and prints before→after deltas
of the dominant roofline term. Each experiment is a (cell, tag, overrides)
triple; overrides split into ArchConfig field replacements and step-builder
options (decode_write).

  PYTHONPATH=src python -m repro.launch.hillclimb [--only TAG]
"""

import argparse
import dataclasses
import json
import sys

from repro.configs import get_config
from repro.launch.dryrun import ART_DIR, run_cell

# (arch, shape, tag, cfg-field overrides, step options)
# Round 1 (fsdp_profile / onehot_write / ctx_parallel / fsdp_micro4) ran
# against v1 (deltas inline below). Round 2 below applies the
# diagnoses from round 1.
EXPERIMENTS = [
    # ---- cell B round 2: decode q-activation replication ----
    # round-1 diagnosis: not the dus write — q heads-sharded over model while
    # the cache is seq-sharded makes GSPMD all-gather the whole cache per
    # token (190 GB). Decode rules now replicate the q *activation* (weights
    # stay sharded) → distributed flash-decode partial merge. predict ≥100×
    # on the collective term.
    ("deepseek_67b", "decode_32k", "decode_rules_v2", {}, {}),
    ("granite_3_2b", "decode_32k", "decode_rules_v2", {}, {}),
    ("llava_next_34b", "decode_32k", "decode_rules_v2", {}, {}),
    ("dbrx_132b", "decode_32k", "decode_rules_v2", {}, {}),

    # ---- cell C round 2: sq-major GQA fold makes ctx parallelism real ----
    # round-1 refutation: the [g,sq] minor-merge broke GSPMD propagation of
    # the q-sequence sharding → attention stayed replicated. The fold is now
    # sq-major; predict attention compute term ≈ /16.
    ("deepseek_coder_33b", "prefill_32k", "ctx_parallel_v2",
     dict(ctx_parallel_attn=True), {}),
    ("llava_next_34b", "prefill_32k", "ctx_parallel_v2",
     dict(ctx_parallel_attn=True), {}),
    ("qwen3_14b", "prefill_32k", "ctx_parallel_v2",
     dict(ctx_parallel_attn=True), {}),

    # ---- cell A round 2: fsdp profile + chunked-mamba-style CE? none —
    # cell A keeps fsdp_profile (2.86×, confirmed). Remaining gap is the 3rd
    # weight gather from full remat; measured-not-fixed (saving gathered
    # weights needs 131 GB).
]

# Round 3: remaining collective-bound small-dense train cells. Same napkin
# math as iteration 2: these models' activation comm (tokens·d) dwarfs their
# per-device compute under TP-SP; ZeRO-3 comm is weight-bound and tiny for a
# 1-3B model (granite: 3×40×135 MB ≈ 16 GB → ~0.33 s vs 3.0 s observed).
ROUND3 = [
    # the ZeRO-3 profile gate needs the explicit fsdp=True opt-in alongside
    # the profile string (distributed/sharding.py) — the override sets both
    ("granite_3_2b", "train_4k", "fsdp_profile",
     dict(sharding_profile="fsdp", fsdp=True), {}),
    ("hubert_xlarge", "train_4k", "fsdp_profile",
     dict(sharding_profile="fsdp", fsdp=True), {}),
    ("recurrentgemma_2b", "train_4k", "fsdp_profile",
     dict(sharding_profile="fsdp", fsdp=True), {}),
]


def load(arch, shape, tag=""):
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(ART_DIR, f"{arch}__{shape}__16x16{suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def report(before, after, tag):
    if not before or not after or "roofline" not in after:
        print(f"  [{tag}] missing artifacts for comparison")
        return
    b, a = before["roofline"], after["roofline"]
    print(f"  {'term':12s} {'before':>10s} {'after':>10s} {'delta':>8s}")
    for term in ("compute_s", "memory_s", "collective_s", "step_time_s"):
        bb, aa = b[term], a[term]
        d = (bb / aa) if aa > 0 else float("inf")
        print(f"  {term:12s} {bb*1e3:9.1f}m {aa*1e3:9.1f}m {d:7.2f}x")
    print(f"  {'mfu':12s} {b['mfu']*100:9.1f}% {a['mfu']*100:9.1f}%")
    bm, am = before["memory"], after["memory"]
    print(f"  {'mem/dev':12s} {bm['peak_estimate_bytes']/1e9:8.1f}G "
          f"{am['peak_estimate_bytes']/1e9:9.1f}G")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--round3", action="store_true")
    args = ap.parse_args(argv)
    experiments = ROUND3 if args.round3 else EXPERIMENTS
    for arch, shape, tag, cfg_over, opts in experiments:
        if args.only and args.only != tag:
            continue
        print(f"\n=== {arch} × {shape} :: {tag} ===", flush=True)
        cfg = get_config(arch)
        if cfg_over:
            cfg = dataclasses.replace(cfg, **cfg_over)
        micro = opts.get("microbatch")
        meta = run_cell(arch, shape, multi_pod=False, tag=f"__{tag}",
                        cfg_override=cfg,
                        decode_write=opts.get("decode_write", "dus"),
                        microbatch=micro)
        report(load(arch, shape), meta, tag)


if __name__ == "__main__":
    sys.exit(main())
