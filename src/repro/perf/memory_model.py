"""Compulsory HBM-traffic model (per device, per step).

XLA's ``bytes accessed`` counts every operand of every HLO op — a fusion-blind
upper bound that overstates TPU HBM traffic by ~5-20×. The roofline *memory
term* should be the compulsory traffic a perfectly-fused TPU program still has
to move:

  * every weight read once per use (fwd + dgrad), optimizer state round-trip,
  * every matmul boundary tensor written/read once (intra-chain elementwise
    ops fuse; matmul outputs must materialise),
  * saved remat residuals written (fwd) + read (bwd) + one recompute pass,
  * logits / KV-cache / recurrent-state streams.

Backward matmul traffic ≈ 2× forward (dgrad + wgrad each re-read one side).
Remat recompute ≈ +1× forward activation traffic.

All dims are divided by the mesh shards that actually shard them (tokens by
DP; features by TP where the rule engine shards them). The same fallback rules
as distributed/sharding.py apply (non-divisible → replicated).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

BF16 = 2
F32 = 4


def _div(n: int, by: int, divisible_required: bool = True) -> float:
    if by <= 1:
        return float(n)
    if n % by == 0:
        return n / by
    return float(n)  # sharding fallback: replicated


@dataclasses.dataclass
class Traffic:
    """Compulsory HBM bytes for one step, broken down by tensor family."""
    weights: float = 0.0
    optimizer: float = 0.0
    activations: float = 0.0
    logits: float = 0.0
    cache: float = 0.0

    @property
    def total(self) -> float:
        """Sum of every traffic family (the roofline memory numerator)."""
        return (self.weights + self.optimizer + self.activations
                + self.logits + self.cache)

    def as_dict(self):
        """Flat dict form (artifact/JSON friendly), including the total."""
        return {"weights": self.weights, "optimizer": self.optimizer,
                "activations": self.activations, "logits": self.logits,
                "cache": self.cache, "total": self.total}


def _layer_param_bytes(cfg, kind: str, tp: int) -> float:
    d = cfg.d_model
    p = 0.0
    if kind == "attn":
        p += _div(cfg.num_heads * cfg.head_dim, tp) * d * 2   # wq, wo
        p += _div(cfg.num_kv_heads * cfg.head_dim, tp) * d * 2
    if kind in ("attn", "rec") and cfg.d_ff:
        if cfg.moe is not None:
            m = cfg.moe
            p += _div(m.num_experts, tp) * 3 * d * m.d_ff_expert
            p += d * m.num_experts                            # router
            p += m.num_shared_experts * 3 * d * m.d_ff_expert
        else:
            p += 3 * d * _div(cfg.d_ff, tp)
    if kind == "rec":
        dr = cfg.rglru.d_rnn
        p += 3 * d * _div(dr, tp) + 2 * _div(dr, tp) * dr
    if kind == "ssm":
        mc = cfg.mamba
        di = _div(mc.d_inner, tp)
        p += 3 * d * di + di * (2 * mc.ssm_state + 2 * mc.dt_rank)
    return p * BF16


def _layer_act_bytes(cfg, kind: str, tokens_local: float, tp: int,
                     seq_kv: Optional[float] = None) -> float:
    """Matmul-boundary tensors per layer, forward, bytes (written + read)."""
    d = cfg.d_model
    t = tokens_local
    a = 2 * t * d                                  # block input read + out write
    if kind == "attn":
        heads_io = (_div(cfg.num_heads * cfg.head_dim, tp)
                    + 2 * _div(cfg.num_kv_heads * cfg.head_dim, tp))
        a += 2 * t * heads_io                      # qkv write+read
        a += 2 * t * _div(cfg.num_heads * cfg.head_dim, tp)  # attn out
    if kind in ("attn", "rec") and cfg.d_ff:
        if cfg.moe is not None:
            m = cfg.moe
            cap_blowup = m.top_k * m.capacity_factor
            a += 2 * t * cap_blowup * (d + _div(m.d_ff_expert, 1))
            a += 2 * t * m.num_shared_experts * m.d_ff_expert
        else:
            a += 2 * t * 2 * _div(cfg.d_ff, tp)    # gated hidden write+read
    if kind == "rec":
        a += 6 * t * _div(cfg.rglru.d_rnn, tp)
    if kind == "ssm":
        a += 8 * t * _div(cfg.mamba.d_inner, tp)
    return a * BF16


def train_traffic(cfg, shape, *, dp: int, tp: int, fsdp: bool) -> Traffic:
    """Per-device compulsory bytes for one train step (module docstring)."""
    t = Traffic()
    tokens_local = shape.global_batch * shape.seq_len / dp
    storage_shards = tp * (dp if fsdp else 1)
    vocab_local = _div(cfg.vocab_size, tp)
    period = len(cfg.block_pattern)

    total_params_local = 0.0
    for i in range(cfg.num_layers):
        kind = cfg.block_pattern[i % period]
        lp = _layer_param_bytes(cfg, kind, tp)
        total_params_local += lp
        # fwd read + dgrad read + wgrad write(grad, f32-equiv ≈ 2×bf16)
        t.weights += 2 * lp + 2 * lp
        act = _layer_act_bytes(cfg, kind, tokens_local, tp)
        # fwd + bwd(2×) + remat recompute(1×) + saved residual round-trip
        t.activations += act * (1 + 2 + (1 if cfg.remat else 0))
    t.activations += cfg.num_layers * tokens_local * cfg.d_model * BF16 * 2
    emb = vocab_local * cfg.d_model * BF16 * 2     # embed + head
    t.weights += 3 * emb
    # optimizer: m, v, master read+write f32 + grad read f32 + param write
    params_storage = (total_params_local + emb) * (tp / storage_shards)
    t.optimizer += params_storage / BF16 * (6 * F32 + F32 + BF16)
    # logits fwd write+read + bwd
    t.logits += 4 * tokens_local * vocab_local * BF16
    return t


def prefill_traffic(cfg, shape, *, dp: int, tp: int) -> Traffic:
    """Per-device compulsory bytes for one prefill pass (incl. cache write)."""
    t = Traffic()
    tokens_local = shape.global_batch * shape.seq_len / dp
    period = len(cfg.block_pattern)
    vocab_local = _div(cfg.vocab_size, tp)
    for i in range(cfg.num_layers):
        kind = cfg.block_pattern[i % period]
        t.weights += _layer_param_bytes(cfg, kind, tp)
        t.activations += _layer_act_bytes(cfg, kind, tokens_local, tp)
        if kind == "attn":
            win = cfg.attn_window or shape.seq_len
            kv = (shape.global_batch / dp) * min(win, shape.seq_len) \
                * _div(cfg.num_kv_heads * cfg.head_dim, tp) * 2
            t.cache += kv * BF16                   # cache write
    t.weights += 2 * vocab_local * cfg.d_model * BF16
    t.logits += 2 * tokens_local * vocab_local * BF16
    return t


def decode_traffic(cfg, shape, *, dp: int, tp: int) -> Traffic:
    """One token for every sequence in the batch."""
    t = Traffic()
    b_local = shape.global_batch / dp
    period = len(cfg.block_pattern)
    vocab_local = _div(cfg.vocab_size, tp)
    for i in range(cfg.num_layers):
        kind = cfg.block_pattern[i % period]
        t.weights += _layer_param_bytes(cfg, kind, tp)
        t.activations += _layer_act_bytes(cfg, kind, b_local, tp)
        if kind == "attn":
            win = cfg.attn_window or shape.seq_len
            eff = min(win, shape.seq_len)
            kvh = _div(cfg.num_kv_heads, tp)
            seq_shard = tp if (cfg.num_kv_heads % tp) else 1
            t.cache += (b_local * kvh * (eff / seq_shard)
                        * cfg.head_dim * 2 * BF16)   # read K and V
        if kind == "rec":
            t.cache += 2 * b_local * _div(cfg.rglru.d_rnn, tp) * F32
        if kind == "ssm":
            mc = cfg.mamba
            t.cache += (2 * b_local * _div(mc.d_inner, tp)
                        * mc.ssm_state * F32)
    t.weights += 2 * vocab_local * cfg.d_model * BF16
    t.logits += 2 * b_local * vocab_local * BF16
    return t


def storage_for(cfg, shape, *, dp: int, tp: int, fsdp: bool) -> dict:
    """Per-device resident HBM bytes (analytic): params + optimizer (train) +
    saved remat residuals + KV-cache/states + a transient working-set term.
    The XLA:CPU scheduler's temp numbers overstate TPU residency (different
    fusion/liveness and no donation aliasing), so `fits_analytic` is reported
    alongside the raw numbers."""
    period = len(cfg.block_pattern)
    storage_shards = tp * (dp if fsdp else 1)
    params_local = 0.0
    for i in range(cfg.num_layers):
        kind = cfg.block_pattern[i % period]
        params_local += _layer_param_bytes(cfg, kind, tp)
    vocab_local = _div(cfg.vocab_size, tp)
    params_local += 2 * vocab_local * cfg.d_model * BF16
    params_store = params_local * (tp / storage_shards)
    out = {"params": params_store}
    tokens_local = shape.global_batch * shape.seq_len / dp
    if shape.kind == "train":
        out["optimizer"] = params_store / BF16 * 3 * F32  # m, v, master f32
        out["grads"] = params_store / BF16 * F32
        # saved residuals at superblock boundaries (seq additionally sharded
        # by TP under the SP layout)
        from repro.distributed.sharding import uses_fsdp_profile
        seq_shard = tp if (not uses_fsdp_profile(cfg)
                           and shape.seq_len % max(tp, 1) == 0) else 1
        out["residuals"] = (cfg.num_layers * tokens_local * cfg.d_model
                            * BF16 / seq_shard)
        out["logits_buffer"] = tokens_local * vocab_local * F32
        # transient: one superblock's recompute working set
        out["transient"] = _layer_act_bytes(cfg, cfg.block_pattern[0],
                                            tokens_local, tp) * period
    else:
        b_local = shape.global_batch / dp
        cache = 0.0
        for i in range(cfg.num_layers):
            kind = cfg.block_pattern[i % period]
            if kind == "attn":
                win = cfg.attn_window or shape.seq_len
                eff = min(win, shape.seq_len)
                kvh = _div(cfg.num_kv_heads, tp)
                seq_shard = tp if (cfg.num_kv_heads % max(tp, 1)) else 1
                cache += (b_local * kvh * eff / seq_shard * cfg.head_dim
                          * 2 * BF16)
            elif kind == "rec":
                cache += b_local * _div(cfg.rglru.d_rnn, tp) * 4 * F32
            elif kind == "ssm":
                mc = cfg.mamba
                cache += (b_local * _div(mc.d_inner, tp)
                          * (mc.ssm_state + mc.conv_kernel) * F32)
        out["cache"] = cache
        out["transient"] = _layer_act_bytes(
            cfg, cfg.block_pattern[0],
            tokens_local if shape.kind == "prefill" else b_local, tp)
    out["total"] = sum(out.values())
    return out


def traffic_for(cfg, shape, *, dp: int, tp: int, fsdp: bool) -> Traffic:
    """Dispatch to the train/prefill/decode traffic model by shape.kind."""
    if shape.kind == "train":
        return train_traffic(cfg, shape, dp=dp, tp=tp, fsdp=fsdp)
    if shape.kind == "prefill":
        return prefill_traffic(cfg, shape, dp=dp, tp=tp)
    return decode_traffic(cfg, shape, dp=dp, tp=tp)
