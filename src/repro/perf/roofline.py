"""Three-term roofline model for TPU v5e, fed by the dry-run artifacts.

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd-only) and the useful-compute
ratio MODEL_FLOPS / (HLO_FLOPs · chips), which exposes remat/dispatch waste.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# --- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_LINK_BW = 50e9         # bytes/s per link (prescribed ~50 GB/s/link)
HBM_PER_CHIP = 16e9        # v5e HBM capacity


@dataclasses.dataclass
class Roofline:
    """One cell's three-term roofline plus the inputs it was built from."""
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float        # XLA bytes-accessed (fusion-blind upper bound)
    mem_bytes_model: float          # compulsory-traffic model (roofline term)
    coll_bytes_per_dev: float
    chips: int

    @property
    def bound(self) -> str:
        """Which of the three terms dominates ("compute"/"memory"/"collective")."""
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Ideal-overlap model: the step takes max(terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_compute_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — exposes remat/dispatch waste."""
        total = self.hlo_flops_per_dev * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """MODEL_FLOPS-based utilisation at the roofline-ideal step time."""
        t = self.step_time_s
        if t == 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def as_dict(self) -> Dict:
        """Flat dict form for the dry-run/benchmark JSON artifacts."""
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "step_time_s": self.step_time_s, "mfu": self.mfu,
            "model_flops": self.model_flops,
            "useful_compute_ratio": self.useful_compute_ratio,
            "hlo_flops_per_dev": self.hlo_flops_per_dev,
            "hlo_bytes_per_dev_upper_bound": self.hlo_bytes_per_dev,
            "mem_bytes_model": self.mem_bytes_model,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "chips": self.chips,
        }


def model_flops_for(cfg, shape, *, step_kind: str) -> float:
    """6·N·D for train, 2·N·D for fwd-only; MoE uses N_active. Decode D =
    global_batch tokens (one step)."""
    n = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    if step_kind == "train":
        d_tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * d_tokens
    if step_kind == "prefill":
        d_tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * d_tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def build(cfg, shape, *, step_kind: str, chips: int, hlo_flops_per_dev: float,
          hlo_bytes_per_dev: float, coll_bytes_per_dev: float,
          mem_bytes_model: float = 0.0) -> Roofline:
    """Assemble a Roofline from dry-run artifacts (module docstring terms)."""
    mem = mem_bytes_model if mem_bytes_model > 0 else hlo_bytes_per_dev
    return Roofline(
        compute_s=hlo_flops_per_dev / PEAK_FLOPS,
        memory_s=mem / HBM_BW,
        collective_s=coll_bytes_per_dev / ICI_LINK_BW,
        model_flops=model_flops_for(cfg, shape, step_kind=step_kind),
        hlo_flops_per_dev=hlo_flops_per_dev,
        hlo_bytes_per_dev=hlo_bytes_per_dev,
        mem_bytes_model=mem,
        coll_bytes_per_dev=coll_bytes_per_dev,
        chips=chips,
    )
