"""Performance models: HBM-traffic accounting (paper §2.3), the three-term
roofline, compiled-HLO collective parsing, and split-KV decode launch
autotuning (perf/autotune.py — cost model + persistent plan cache)."""

from repro.perf.autotune import (AutotuneCache, DecodeShape, LaunchPlan,
                                 plan_decode, plan_decode_persistent,
                                 predict_time)
from repro.perf.hlo_analysis import CollectiveStats, collective_stats
from repro.perf.roofline import (HBM_BW, HBM_PER_CHIP, ICI_LINK_BW, PEAK_FLOPS,
                                 Roofline, build, model_flops_for)

__all__ = ["AutotuneCache", "CollectiveStats", "DecodeShape", "LaunchPlan",
           "collective_stats", "plan_decode", "plan_decode_persistent",
           "predict_time",
           "HBM_BW", "HBM_PER_CHIP", "ICI_LINK_BW", "PEAK_FLOPS", "Roofline",
           "build", "model_flops_for"]
