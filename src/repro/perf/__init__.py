from repro.perf.hlo_analysis import CollectiveStats, collective_stats
from repro.perf.roofline import (HBM_BW, HBM_PER_CHIP, ICI_LINK_BW, PEAK_FLOPS,
                                 Roofline, build, model_flops_for)

__all__ = ["CollectiveStats", "collective_stats", "HBM_BW", "HBM_PER_CHIP",
           "ICI_LINK_BW", "PEAK_FLOPS", "Roofline", "build", "model_flops_for"]
