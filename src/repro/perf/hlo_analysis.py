"""Collective-traffic extraction from compiled HLO text.

``cost_analysis()`` has no collective-bytes entry, so we parse the compiled
module: for every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute we take the result (tuple) shapes and cost them with the
standard ring-algorithm byte counts per participating device:

    all-reduce       2·S·(n−1)/n      (reduce-scatter + all-gather ring)
    all-gather       S·(n−1)/n        (S = full gathered size)
    reduce-scatter   S·(n−1)          (S = scattered shard size; input S·n)
    all-to-all       S·(n−1)/n
    collective-permute S

n = replica-group size, parsed from either the explicit ``{{0,1,..},..}`` or
the iota ``[g,n]<=[N]`` form. Bytes are per-device; the roofline divides by
per-link bandwidth.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"^\s*(?:%?[\w.\-]+\s*=\s*)?(\(?[\w\[\],\s{}\/]*\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?(?:\.\d+)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


@dataclasses.dataclass
class CollectiveStats:
    """Collective traffic parsed from one compiled-HLO text dump."""
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    total_bytes: float
    ops: List[dict]


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]<=[N]
    return default


def collective_stats(hlo_text: str, *, default_group: int = 1,
                     skip_done: bool = True) -> CollectiveStats:
    """Scan HLO text for collective ops and total their ring-algorithm bytes.

    Async pairs count once (the ``-start`` op); replica-group sizes come from
    the op's ``replica_groups`` attribute, falling back to ``default_group``.
    """
    bytes_by_kind: Dict[str, float] = defaultdict(float)
    count_by_kind: Dict[str, int] = defaultdict(int)
    ops = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        if skip_done and ("-done" in line.split("(")[0]):
            continue  # async pair: count the -start only
        type_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(type_str)
        n = max(2, _group_size(line, default_group))
        if kind == "all-reduce":
            dev_bytes = 2.0 * size * (n - 1) / n
        elif kind == "all-gather":
            dev_bytes = size * (n - 1) / n
        elif kind == "reduce-scatter":
            dev_bytes = size * (n - 1)
        elif kind == "all-to-all":
            dev_bytes = size * (n - 1) / n
        else:  # collective-permute
            dev_bytes = size
        bytes_by_kind[kind] += dev_bytes
        count_by_kind[kind] += 1
        ops.append({"kind": kind, "result_bytes": size, "group": n,
                    "device_bytes": dev_bytes})
    return CollectiveStats(dict(bytes_by_kind), dict(count_by_kind),
                           sum(bytes_by_kind.values()), ops)
