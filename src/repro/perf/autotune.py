"""Launch-parameter autotuning for split-KV flash decode.

Decode is purely memory-bound (the roofline term is HBM bytes = bytes(K) +
bytes(V) — ``perf/memory_model.py``'s cache accounting), so the *only* launch
decision that matters is how to spread that fixed traffic over the machine:

  * too few grid cells (``B·Hkv·num_splits < parallelism``) and HBM sits idle
    behind an under-occupied grid — the headline serving shapes
    (``decode_32k``, ``long_500k``, small continuous-batching batches) live
    here;
  * too many splits and the fixed per-cell cost plus the O(B·Hq·(D+2)) f32
    partial-state merge pass start to dominate.

:func:`predict_time` models exactly that trade-off (LightSeq2's observation
that launch-parameter tuning is first-class kernel work, applied to the
split-KV decode of ``kernels/decode.py``):

    t_attn  = waves(B·Hkv·ns / parallelism) · (split KV bytes / HBM_BW + c₀)
    t_merge = ns·B·Hq·(D+2)·4 bytes / HBM_BW + c₁   (ns > 1 only)

:func:`plan_decode` picks ``(num_splits, block_kv)`` per decode geometry
(:class:`DecodeShape`) from the model, optionally refined by an on-device
timing sweep (pass ``sweep=``; ``benchmarks/decode_split.py`` wires one), and
memoises through a persistent JSON cache (:class:`AutotuneCache` —
``$REPRO_AUTOTUNE_CACHE`` > ``~/.cache/repro/autotune.json`` > repo-local).
``ServingEngine(autotune=True)`` / ``launch/serve.py --autotune`` call this
once per engine build; the jitted decode step then runs with a static
``num_splits``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from pathlib import Path
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.perf.memory_model import BF16
from repro.perf.roofline import HBM_BW

# Grid cells the hardware overlaps: TPU cores × the Mosaic pipeline depth a
# memory-bound kernel sustains. A modelling constant, not a probed value —
# only the *ratio* of occupancy between candidate plans matters to the argmin.
DEFAULT_PARALLELISM = 8

GRID_CELL_OVERHEAD_S = 1e-6   # c₀: per-wave dispatch/pipeline-fill cost
MERGE_OVERHEAD_S = 2e-6       # c₁: the extra merge pass's fixed cost

SPLIT_CANDIDATES = (1, 2, 4, 8, 16, 32)
BLOCK_KV_CANDIDATES = (128, 256, 512, 1024)

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"


@dataclasses.dataclass(frozen=True)
class DecodeShape:
    """The launch-relevant decode geometry — the autotune cache key.

    ``page_size == 0`` means a contiguous cache (``block_kv`` tunable);
    ``page_size > 0`` pins ``block_kv`` to the page size (pages are the DMA
    unit — the block table gathers whole pages).
    """
    batch: int
    hkv: int                 # KV heads (grid parallelism, with batch)
    group: int               # Hq // Hkv (merge-pass rows = batch·hkv·group)
    kv_len: int              # cache length the plan is tuned for
    head_dim: int
    page_size: int = 0
    dtype_bytes: int = BF16

    def key(self) -> str:
        """Stable string form used as the JSON cache key."""
        return (f"b{self.batch}.h{self.hkv}.g{self.group}.s{self.kv_len}"
                f".d{self.head_dim}.p{self.page_size}.by{self.dtype_bytes}")


@dataclasses.dataclass(frozen=True)
class LaunchPlan:
    """A chosen (num_splits, block_kv) with its predicted/measured time."""
    num_splits: int
    block_kv: int
    time_s: float            # cost-model prediction, or sweep measurement
    source: str = "model"    # "model" | "sweep" | "cache"


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def predict_time(shape: DecodeShape, num_splits: int, block_kv: int, *,
                 parallelism: int = DEFAULT_PARALLELISM,
                 hbm_bw: float = HBM_BW) -> float:
    """Cost-model seconds for one decode launch at the given parameters.

    Occupancy vs. merge overhead (module docstring): each of the
    ``B·Hkv·num_splits`` grid cells streams its KV slice once; cells beyond
    the hardware's concurrent capacity serialize into waves; splitting adds
    one O(ns·B·Hq·(D+2)) f32 pass to merge the partial states.
    """
    nk = max(1, _ceil_div(shape.kv_len, block_kv))
    num_splits = max(1, min(num_splits, nk))
    blocks_per_split = _ceil_div(nk, num_splits)
    kv_bytes_per_cell = (2 * blocks_per_split * block_kv * shape.head_dim
                         * shape.dtype_bytes)
    cells = shape.batch * shape.hkv * num_splits
    waves = _ceil_div(cells, parallelism)
    t_attn = waves * (kv_bytes_per_cell / hbm_bw + GRID_CELL_OVERHEAD_S)
    if num_splits == 1:
        return t_attn
    hq = shape.hkv * shape.group
    merge_bytes = num_splits * shape.batch * hq * (shape.head_dim + 2) * 4
    # the merge reads every partial and writes one final state (≈2× traffic)
    t_merge = 2 * merge_bytes / hbm_bw + MERGE_OVERHEAD_S
    return t_attn + t_merge


def candidate_plans(shape: DecodeShape) -> Sequence[Tuple[int, int]]:
    """(num_splits, block_kv) pairs worth considering for a shape.

    Paged caches fix ``block_kv = page_size``; contiguous caches sweep the
    8-row-aligned block candidates no larger than the cache. Split counts are
    capped so every split owns at least one KV block.
    """
    if shape.page_size > 0:
        blocks = (shape.page_size,)
    else:
        blocks = tuple(b for b in BLOCK_KV_CANDIDATES if b <= shape.kv_len)
        if not blocks:
            blocks = (max(8, _ceil_div(shape.kv_len, 8) * 8),)
    pairs = []
    for bk in blocks:
        nk = max(1, _ceil_div(shape.kv_len, bk))
        for ns in SPLIT_CANDIDATES:
            if ns <= nk:
                pairs.append((ns, bk))
    return pairs


def plan_decode(shape: DecodeShape, *,
                sweep: Optional[Callable[[int, int], float]] = None,
                cache: Optional["AutotuneCache"] = None,
                parallelism: int = DEFAULT_PARALLELISM) -> LaunchPlan:
    """Choose launch parameters for one decode geometry.

    Pure by default — the cost model alone ranks :func:`candidate_plans`, so
    a valid plan never needs a device. ``sweep`` is an optional measured
    refinement: a callable ``(num_splits, block_kv) -> seconds`` (e.g. a
    wall-clock timer over the real kernel — ``benchmarks/decode_split.py``
    builds one) applied to the model's top candidates. ``cache`` memoises
    per :meth:`DecodeShape.key`; hits skip both model and sweep.
    """
    if cache is not None:
        hit = cache.get(shape)
        if hit is not None:
            return hit
    ranked = sorted(candidate_plans(shape),
                    key=lambda p: predict_time(shape, *p,
                                               parallelism=parallelism))
    ns, bk = ranked[0]
    plan = LaunchPlan(num_splits=ns, block_kv=bk,
                      time_s=predict_time(shape, ns, bk,
                                          parallelism=parallelism))
    if sweep is not None:
        best = None
        for ns, bk in ranked[:4]:          # measure only the model's top-4
            t = sweep(ns, bk)
            if best is None or t < best.time_s:
                best = LaunchPlan(num_splits=ns, block_kv=bk, time_s=t,
                                  source="sweep")
        plan = best
    if cache is not None:
        cache.put(shape, plan)
    return plan


def plan_decode_persistent(shape: DecodeShape, **kw) -> LaunchPlan:
    """:func:`plan_decode` through the default persistent cache.

    Owns the cache lifecycle for callers that just want a plan: open the
    default cache, plan (hits short-circuit), persist — swallowing OSError so
    read-only cache locations degrade to planning without memoisation. The
    one entry point the serving engine and launcher share.
    """
    cache = AutotuneCache()
    plan = plan_decode(shape, cache=cache, **kw)
    try:
        cache.save()
    except OSError:
        pass                       # read-only filesystems: plan still valid
    return plan


class AutotuneCache:
    """Persistent JSON store of launch plans, keyed by decode geometry.

    Resolution order for the backing file: explicit ``path`` argument >
    ``$REPRO_AUTOTUNE_CACHE`` > ``~/.cache/repro/autotune.json`` > a
    repo-local ``.autotune_cache.json`` (when no home is writable). Writes
    are atomic (tempfile + rename) so concurrent engines can share a cache.
    """

    def __init__(self, path: Optional[os.PathLike] = None):
        self.path = Path(path) if path is not None else self.default_path()
        self._plans: Dict[str, LaunchPlan] = {}
        self.load()

    @staticmethod
    def default_path() -> Path:
        """The environment-overridable cache location (class docstring)."""
        env = os.environ.get(CACHE_ENV)
        if env:
            return Path(env)
        try:
            home = Path.home()
        except RuntimeError:
            home = None
        if home is not None:
            return home / ".cache" / "repro" / "autotune.json"
        return Path(".autotune_cache.json")

    def load(self) -> None:
        """Re-read the backing file (missing/corrupt files load as empty)."""
        self._plans = {}
        try:
            raw = json.loads(self.path.read_text())
        except (OSError, ValueError):
            return
        for key, rec in raw.items():
            try:
                self._plans[key] = LaunchPlan(
                    num_splits=int(rec["num_splits"]),
                    block_kv=int(rec["block_kv"]),
                    time_s=float(rec["time_s"]),
                    source="cache")
            except (KeyError, TypeError, ValueError):
                continue                   # skip malformed entries, keep rest

    def get(self, shape: DecodeShape) -> Optional[LaunchPlan]:
        """Cached plan for this exact geometry, or None."""
        return self._plans.get(shape.key())

    def put(self, shape: DecodeShape, plan: LaunchPlan) -> None:
        """Record a plan in memory (call :meth:`save` to persist)."""
        self._plans[shape.key()] = plan

    def save(self) -> None:
        """Atomically persist every recorded plan to the backing file."""
        self.path.parent.mkdir(parents=True, exist_ok=True)
        payload = {k: {"num_splits": p.num_splits, "block_kv": p.block_kv,
                       "time_s": p.time_s, "source": p.source}
                   for k, p in self._plans.items()}
        fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
