from repro.runtime.steps import (ServeArtifacts, TrainArtifacts,
                                 make_serve_steps, make_train_step)
from repro.runtime.trainer import StragglerMonitor, Trainer, TrainerConfig

__all__ = ["ServeArtifacts", "TrainArtifacts", "make_serve_steps",
           "make_train_step", "StragglerMonitor", "Trainer", "TrainerConfig"]
