"""Fault-tolerant training loop: checkpoint/restart, preemption, stragglers.

Production posture for 1000+ nodes:

* **Resume-from-latest** on start; checkpoints are atomic (checkpoint/ckpt.py)
  and mesh-agnostic, so a restart may use a *different* device count/mesh
  (elastic re-scaling) — the restore path re-shards host arrays.
* **Preemption**: SIGTERM/SIGINT installs a "checkpoint then exit" request;
  the loop commits a final checkpoint at the next step boundary (the standard
  maintenance-event protocol on TPU pods).
* **Straggler monitor**: per-step wall times; steps slower than
  ``threshold × rolling-median`` are logged with their index. On real pods
  this feeds the scheduler's hot-spare replacement; here it drives the
  metrics surfaced to the launcher (and tests inject synthetic stragglers).
* **Data determinism**: the synthetic pipeline is a pure function of step, so
  resume consumes identical batches — asserted by tests/test_fault_tolerance.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.data import DataConfig, make_batch


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_threshold: float = 3.0
    log_every: int = 10
    async_ckpt: bool = True


class StragglerMonitor:
    def __init__(self, threshold: float, window: int = 50):
        self.threshold = threshold
        self.times: deque = deque(maxlen=window)
        self.flagged: list = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 5:
            med = float(np.median(self.times))
            if dt > self.threshold * med:
                self.flagged.append((step, dt, med))
                is_straggler = True
        self.times.append(dt)
        return is_straggler


class Trainer:
    def __init__(self, *, arts, data_cfg: DataConfig, tcfg: TrainerConfig,
                 batch_shardings=None, hooks: Optional[Dict[str, Callable]] = None):
        self.arts = arts            # TrainArtifacts from make_train_step
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        self.batch_shardings = batch_shardings
        self.hooks = hooks or {}
        self.monitor = StragglerMonitor(tcfg.straggler_threshold)
        self._preempted = False
        self._pending_save = None
        self.metrics_log: list = []

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        for sig in (signal.SIGTERM,):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # non-main thread (tests)

    def request_preemption(self):
        """Test hook: simulate a maintenance event."""
        self._preempted = True

    def _state_tree(self, params, opt_state, step):
        return {"params": params, "opt": opt_state,
                "step": jnp.asarray(step, jnp.int32)}

    def _save(self, params, opt_state, step):
        tree = self._state_tree(params, opt_state, step)
        if self.tcfg.async_ckpt:
            if self._pending_save is not None:
                self._pending_save.join()
            self._pending_save = ckpt.save_async(
                self.tcfg.ckpt_dir, step, tree, keep=self.tcfg.keep)
        else:
            ckpt.save(self.tcfg.ckpt_dir, step, tree, keep=self.tcfg.keep)

    def _restore_or_init(self, key):
        params, opt_state, _ = self.arts.init_fn(key)
        start = 0
        latest = ckpt.latest_step(self.tcfg.ckpt_dir)
        if latest is not None:
            like = self._state_tree(params, opt_state, 0)
            shardings = None
            if self.arts.shardings is not None:
                shardings = {"params": self.arts.shardings["params"],
                             "opt": self.arts.shardings["opt"],
                             "step": None}
            tree = ckpt.restore(self.tcfg.ckpt_dir, latest, like,
                                shardings=shardings)
            params, opt_state = tree["params"], tree["opt"]
            start = int(tree["step"]) + 1
        return params, opt_state, start

    def _place(self, batch):
        if self.batch_shardings is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        return {k: jax.device_put(v, self.batch_shardings.get(k))
                for k, v in batch.items()}

    def run(self, total_steps: int, key=None) -> Dict[str, Any]:
        self._install_signal_handlers()
        key = jax.random.PRNGKey(0) if key is None else key
        params, opt_state, start = self._restore_or_init(key)
        step = start
        while step < total_steps and not self._preempted:
            t0 = time.perf_counter()
            batch = self._place(make_batch(self.data_cfg, step))
            if "pre_step" in self.hooks:  # test hook (straggler injection)
                self.hooks["pre_step"](step)
            params, opt_state, metrics = self.arts.step_fn(
                params, opt_state, batch, jnp.int32(step))
            loss = float(metrics["loss"])  # also syncs the step
            dt = time.perf_counter() - t0
            self.monitor.observe(step, dt)
            self.metrics_log.append({"step": step, "loss": loss, "dt": dt})
            if step % self.tcfg.log_every == 0:
                print(f"step {step:6d} loss {loss:8.4f} "
                      f"gnorm {float(metrics.get('grad_norm', 0)):6.3f} "
                      f"dt {dt*1e3:8.1f}ms", flush=True)
            if (step + 1) % self.tcfg.ckpt_every == 0:
                self._save(params, opt_state, step)
            step += 1
        # final / preemption checkpoint at the step boundary
        self._save(params, opt_state, step - 1)
        if self._pending_save is not None:
            self._pending_save.join()
        return {"params": params, "opt": opt_state, "stop_step": step,
                "preempted": self._preempted,
                "stragglers": list(self.monitor.flagged)}
