"""jit-compiled train / prefill / decode steps, mesh-aware.

``make_train_step`` / ``make_serve_steps`` return jitted callables with
in/out shardings derived from the sharding-rule engine; with ``mesh=None``
they degrade to single-device functions (smoke tests, examples).

These builders are the single source for the launcher, the dry-run, the
benchmarks and the distributed tests — what the dry-run compiles is exactly
what the trainer runs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (ShardingRules, default_rules,
                                        vocab_pad_for)
from repro.models import lm
from repro.models.layers import Ctx
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule


def _make_ctx(cfg, rules: Optional[ShardingRules], impl: str, seed,
              deterministic: bool, decode: bool = False,
              xla_chunk: int = 1024, xla_unroll: bool = False,
              decode_write: str = "dus", mesh=None,
              num_splits: int = 1, block_kv: int = 128) -> Ctx:
    return Ctx(constrain=rules.constrain if rules is not None else None,
               impl=impl, deterministic=deterministic, seed=seed,
               decode=decode, xla_chunk=xla_chunk, xla_unroll=xla_unroll,
               decode_write=decode_write, mesh=mesh, num_splits=num_splits,
               block_kv=block_kv)


@dataclasses.dataclass
class TrainArtifacts:
    step_fn: Any            # (params, opt_state, batch, step) → (p, o, metrics)
    init_fn: Any            # key → (params, opt_state)
    shardings: Any          # dict: params/opt_state/batch NamedShardings
    rules: Optional[ShardingRules]


def make_train_step(cfg, *, mesh=None, opt: AdamWConfig = AdamWConfig(),
                    impl: str = "xla", total_steps: int = 10000,
                    warmup_steps: int = 100, microbatch: Optional[int] = None,
                    aux_weight: float = 0.01, xla_chunk: int = 1024,
                    xla_unroll: bool = False,
                    donate: bool = True) -> TrainArtifacts:
    rules = default_rules(mesh, cfg) if mesh is not None else None
    vocab_pad = vocab_pad_for(mesh) if mesh is not None else 1

    def init_fn(key):
        params, specs = lm.init_params(cfg, key, vocab_pad_to=vocab_pad)
        opt_state = adamw_init(params, opt)
        return params, opt_state, specs

    def loss_of(params, batch, seed):
        ctx = _make_ctx(cfg, rules, impl, seed,
                        deterministic=(cfg.dropout_rate == 0.0),
                        xla_chunk=xla_chunk, xla_unroll=xla_unroll)
        return lm.loss_fn(cfg, params, batch, ctx, aux_weight=aux_weight)

    def train_step(params, opt_state, batch, step):
        seed = (step.astype(jnp.uint32) * jnp.uint32(2654435761)
                ).astype(jnp.int32)  # per-step dropout stream
        if microbatch is None:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch, seed)
        else:
            # gradient accumulation over microbatches (PP-style scheduling
            # substrate): scan over batch splits, mean the grads.
            n_micro = batch["labels"].shape[0] // microbatch

            def split(x):
                return x.reshape((n_micro, microbatch) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mb, seed)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum), ms = jax.lax.scan(acc, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n_micro, g_sum)
            loss = l_sum / n_micro
            metrics = jax.tree.map(lambda x: x[-1], ms)
        lr = cosine_schedule(step, warmup_steps, total_steps, opt.lr)
        params, opt_state, om = adamw_update(grads, opt_state, params, opt)
        metrics = dict(metrics, **om, lr=lr, loss=loss)
        return params, opt_state, metrics

    shardings = None
    if mesh is not None:
        params_shape, specs = lm.abstract_params(cfg, vocab_pad_to=vocab_pad)
        p_shard = rules.tree_shardings(params_shape, specs)
        o_shard = _opt_shardings(p_shard, opt)
        b_shard = {
            "tokens": rules.sharding_for(("batch", None), None),
            "labels": rules.sharding_for(("batch", None), None),
            "embeds": rules.sharding_for(("batch", None, None), None),
            # packed (varlen) batches ride along with the same batch sharding
            "segment_ids": rules.sharding_for(("batch", None), None),
            "positions": rules.sharding_for(("batch", None), None),
        }
        repl = NamedSharding(mesh, P())
        step_fn = jax.jit(
            train_step,
            in_shardings=(p_shard, o_shard, None, repl),
            out_shardings=(p_shard, o_shard, repl),
            donate_argnums=(0, 1) if donate else ())
        shardings = {"params": p_shard, "opt": o_shard, "batch": b_shard}
    else:
        step_fn = jax.jit(train_step, donate_argnums=(0, 1) if donate else ())

    return TrainArtifacts(step_fn=step_fn, init_fn=init_fn,
                          shardings=shardings, rules=rules)


def _opt_shardings(p_shard, opt: AdamWConfig):
    from repro.optim.adamw import AdamWState
    none_spec = None
    return AdamWState(
        step=NamedSharding(list(jax.tree.leaves(p_shard))[0].mesh, P()),
        m=p_shard, v=p_shard,
        master=p_shard if opt.keep_master else None)


@dataclasses.dataclass
class ServeArtifacts:
    prefill_fn: Any
    decode_fn: Any
    cache_init_fn: Any
    rules: Optional[ShardingRules]          # prefill/param rules
    rules_decode: Optional[ShardingRules] = None
    chunk_prefill_fn: Any = None            # paged only: chunked/suffix prefill
    verify_fn: Any = None                   # paged only: speculative verify-k


def make_serve_steps(cfg, *, mesh=None, impl: str = "xla", max_len: int = 2048,
                     batch: int = 1, xla_chunk: int = 1024,
                     xla_unroll: bool = False,
                     decode_write: str = "dus",
                     num_splits: int = 1, block_kv: int = 128,
                     paged=None) -> ServeArtifacts:
    """paged: optional serving.PagedCacheConfig — switches the cache to a
    global page pool with block-table decode and segment-aware packed
    prefill (the serving subsystem's jitted steps; see docs/serving.md).
    The paged signatures differ from the contiguous ones:

      prefill_fn(params, tokens, segment_ids, positions, dest, state_slots,
                 caches)
          → (logits [B,S,Vpad], caches)     # packed prompts, B prefill rows
      decode_fn(params, token, caches, block_tables, kv_len)
          → (logits [B,Vpad], caches)       # B = paged.max_batch slots
      chunk_prefill_fn(params, tokens, positions, dest, token_tables,
                       token_kv_len, state_slots, state_local, caches)
          → (logits [B,S,Vpad], caches)     # chunked/suffix prefill spans
                                            # (global positions; per-token
                                            # block-table attention)

    state_slots/state_local [B,S] route hybrid SSM/recurrent archs' fixed
    per-slot state rows (each token's decode slot and within-span offset;
    -1/0 for padding) — attention-only archs pass them too and XLA prunes
    the unused inputs.  Decode derives row liveness from kv_len > 0, so its
    signature is unchanged; the verify step stays attention-only (the
    engine rejects speculation on recurrent archs — state can't roll back).
      verify_fn(params, tokens, positions, dest, token_tables,
                token_kv_len, caches)
          → (logits [B,W,Vpad], caches)     # speculative verify-k: same
                                            # per-token primitive, B =
                                            # max_batch decode rows of
                                            # width W = k+1, decode-path
                                            # sharding rules + num_splits

    num_splits / block_kv: split-KV launch parameters for the decode step
    (static — baked into the jitted step; pick both with perf/autotune.py or
    let ``ServingEngine(autotune=True)`` do it). The paged decode ignores
    ``block_kv`` — its KV block is pinned to the page size.
    """
    if paged is not None:
        # distributed pool: the page dim shards over the mesh's model axis
        # (page-aligned — pages never straddle shards); decode runs per-shard
        # local attention + online-softmax partial merge via the shard_map
        # paths in distributed/paged.py. mesh=None keeps the single-host path.
        rules = rules_dec = None
        if mesh is not None:
            from repro.distributed.paged import pool_shard_count
            n_shards = pool_shard_count(mesh)
            if paged.num_shards != n_shards:
                raise ValueError(
                    f"PagedCacheConfig.num_shards={paged.num_shards} must "
                    f"equal the mesh's model-axis size {n_shards} (the "
                    f"allocator reserves one trash page per pool shard)")
            # the page-aligned split itself is validated by PagedCacheConfig
            rules = default_rules(mesh, cfg, serve=True)
            rules_dec = default_rules(mesh, cfg, serve=True, decode=True)

        def cache_init():
            caches = lm.init_paged_cache(cfg, paged)
            if mesh is not None:
                # pool leaf [(n_super,) Hkv, num_pages, page_size, D]: the
                # page axis is always ndim-3 and shards over the model axis;
                # recurrent-state rows (hybrid archs) are tiny and replicate
                from jax.tree_util import tree_map_with_path

                def put(path, x):
                    pool = getattr(path[-1], "key", None) in ("k_pages",
                                                              "v_pages")
                    spec = (P(*(None,) * (x.ndim - 3), "model", None, None)
                            if pool else P())
                    return jax.device_put(x, NamedSharding(mesh, spec))

                caches = tree_map_with_path(put, caches)
            return caches

        def prefill_fn(params, tokens, segment_ids, positions, dest,
                       state_slots, caches):
            ctx = _make_ctx(cfg, rules, impl, 0, True, xla_chunk=xla_chunk,
                            xla_unroll=xla_unroll, mesh=mesh)
            return lm.paged_prefill(cfg, params, ctx, tokens, segment_ids,
                                    positions, dest, caches, state_slots)

        def decode_fn(params, token, caches, block_tables, kv_len):
            ctx = _make_ctx(cfg, rules_dec, impl, 0, True, xla_chunk=xla_chunk,
                            decode_write=decode_write, mesh=mesh,
                            num_splits=num_splits)
            return lm.paged_decode_step(cfg, params, ctx, token, caches,
                                        block_tables, kv_len)

        def chunk_prefill_fn(params, tokens, positions, dest, token_tables,
                             token_kv_len, state_slots, state_local, caches):
            ctx = _make_ctx(cfg, rules, impl, 0, True, xla_chunk=xla_chunk,
                            xla_unroll=xla_unroll, mesh=mesh)
            return lm.paged_chunk_prefill(cfg, params, ctx, tokens, positions,
                                          dest, token_tables, token_kv_len,
                                          caches, state_slots, state_local)

        def verify_fn(params, tokens, positions, dest, token_tables,
                      token_kv_len, caches):
            # decode-path rules + split-KV launch params: the verify step is
            # the latency-bound step it replaces, just k+1 tokens wide
            ctx = _make_ctx(cfg, rules_dec, impl, 0, True, xla_chunk=xla_chunk,
                            decode_write=decode_write, mesh=mesh,
                            num_splits=num_splits)
            return lm.paged_verify_step(cfg, params, ctx, tokens, positions,
                                        dest, token_tables, token_kv_len,
                                        caches)

        # all steps donate the page pools (the dominant serving tensors):
        # the caller always threads the returned caches into the next call
        return ServeArtifacts(prefill_fn=jax.jit(prefill_fn,
                                                 donate_argnums=(6,)),
                              decode_fn=jax.jit(decode_fn, donate_argnums=(2,)),
                              chunk_prefill_fn=jax.jit(chunk_prefill_fn,
                                                       donate_argnums=(8,)),
                              verify_fn=jax.jit(verify_fn, donate_argnums=(6,)),
                              cache_init_fn=cache_init, rules=rules,
                              rules_decode=rules_dec)

    # prefill and decode get DIFFERENT activation rules: prefill behaves
    # like a forward train pass (FSDP weight gathers amortise over the whole
    # sequence); decode must avoid per-token weight/cache gathers.
    rules = default_rules(mesh, cfg, serve=True) if mesh is not None else None
    rules_dec = (default_rules(mesh, cfg, serve=True, decode=True)
                 if mesh is not None else None)
    vocab_pad = vocab_pad_for(mesh) if mesh is not None else 1

    def cache_init():
        return lm.init_cache(cfg, batch, max_len)

    def prefill_fn(params, tokens, embeds, caches):
        # positional-only: jit in_shardings forbids kwargs
        ctx = _make_ctx(cfg, rules, impl, 0, True, xla_chunk=xla_chunk,
                        xla_unroll=xla_unroll)
        return lm.prefill(cfg, params, ctx, tokens=tokens, embeds=embeds,
                          caches=caches)

    def decode_fn(params, token, caches, position):
        ctx = _make_ctx(cfg, rules_dec, impl, 0, True, xla_chunk=xla_chunk,
                        decode_write=decode_write, num_splits=num_splits,
                        block_kv=block_kv)
        return lm.decode_step(cfg, params, ctx, token, caches, position)

    if mesh is not None:
        params_shape, specs = lm.abstract_params(cfg, vocab_pad_to=vocab_pad)
        p_shard = rules.tree_shardings(params_shape, specs)
        # the KV cache is donated by BOTH steps: prefill writes the prompt
        # K/V into it and decode updates it in place (halves the serving
        # memory footprint — caches are the dominant serving tensor); every
        # caller threads the returned caches into the next call
        prefill_jit = jax.jit(prefill_fn,
                              in_shardings=(p_shard, None, None, None),
                              donate_argnums=(3,))
        decode_jit = jax.jit(decode_fn, donate_argnums=(2,))
        return ServeArtifacts(prefill_fn=prefill_jit, decode_fn=decode_jit,
                              cache_init_fn=cache_init, rules=rules,
                              rules_decode=rules_dec)
    return ServeArtifacts(prefill_fn=jax.jit(prefill_fn, donate_argnums=(3,)),
                          decode_fn=jax.jit(decode_fn, donate_argnums=(2,)),
                          cache_init_fn=cache_init, rules=rules,
                          rules_decode=rules_dec)
