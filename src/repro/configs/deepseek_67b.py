"""deepseek-67b — assigned architecture config (see configs/__init__ for fields)."""

import dataclasses

from repro.configs import ArchConfig, MoEConfig, RGLRUConfig, MambaConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=102400,
    fsdp=True,
    sharding_profile="fsdp",  # TP-SP is 8x collective-bound at train_4k;
                               # ZeRO-3 profile: 45.6s->15.9s collective,
                               # MFU 18.5%->52.8% (SSPerf iteration 2)
    notes="llama-arch dense 67B [arXiv:2401.02954; hf]. FSDP+SP required: "
          "95 layers x 1GB residuals do not fit without both.",
)
SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=0, fsdp=False)
