"""deepseek-coder-33b — assigned architecture config (see configs/__init__ for fields)."""

import dataclasses

from repro.configs import ArchConfig, MoEConfig, RGLRUConfig, MambaConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32256,
    fsdp=True,
    ctx_parallel_attn=True,  # 56 heads vs 16-way axis (SSPerf iteration 4)
    notes="llama-arch dense 33B [arXiv:2401.14196; hf]",
)
SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=0, fsdp=False)
