"""deepseek-moe-16b — assigned architecture config (see configs/__init__ for fields)."""

import dataclasses

from repro.configs import ArchConfig, MoEConfig, RGLRUConfig, MambaConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared_experts=2, capacity_factor=1.25, group_size=256),
    notes="2 shared + 64 routed top-6 fine-grained [arXiv:2401.06066; hf]. "
          "Small dispatch groups (256) keep the GShard dispatch-einsum "
          "overhead <8% of expert FLOPs at d_ff_expert=1408.",
)
SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=64, vocab_size=256, head_dim=0,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                  num_shared_experts=2, group_size=64))
