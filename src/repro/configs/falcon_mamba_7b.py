"""falcon-mamba-7b — assigned architecture config (see configs/__init__ for fields)."""

import dataclasses

from repro.configs import ArchConfig, MoEConfig, RGLRUConfig, MambaConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024, head_dim=0,
    block_pattern=("ssm",),
    mamba=MambaConfig(d_inner=8192, ssm_state=16, conv_kernel=4),
    sub_quadratic=True,
    notes="mamba1 arch, attention-free [arXiv:2410.05355; unverified]. "
          "SparkAttention inapplicable (attention-free arch); "
          "arch fully supported via the selective-scan mixer.",
)
SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, vocab_size=256,
    mamba=MambaConfig(d_inner=128, ssm_state=4, conv_kernel=4))
