"""qwen3-14b — assigned architecture config (see configs/__init__ for fields)."""

import dataclasses

from repro.configs import ArchConfig, MoEConfig, RGLRUConfig, MambaConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=17408, vocab_size=151936, head_dim=128,
    qk_norm=True,
    fsdp=True,
    ctx_parallel_attn=True,  # 40 heads vs 16-way axis (SSPerf iteration 4)
    notes="qk-norm + GQA [hf:Qwen/Qwen3-8B; hf]. fsdp=True: 40 heads do not "
          "divide the 16-way model axis, so attention projections cannot TP "
          "- without FSDP they (and their optimizer state) replicate to "
          "46 GB/device (caught by the v0 dry-run).",
)
SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16)
