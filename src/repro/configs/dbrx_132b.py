"""dbrx-132b — assigned architecture config (see configs/__init__ for fields)."""

import dataclasses

from repro.configs import ArchConfig, MoEConfig, RGLRUConfig, MambaConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752,
                  capacity_factor=1.25, group_size=512),
    fsdp=True,
    notes="16 experts top-4 fine-grained [hf:databricks/dbrx-base; "
          "unverified]. Experts shard 1/device on the 16-way model axis.",
)
SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=0, fsdp=False,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32, group_size=64))
