"""granite-3-2b — assigned architecture config (see configs/__init__ for fields)."""

import dataclasses

from repro.configs import ArchConfig, MoEConfig, RGLRUConfig, MambaConfig

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense",
    num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=49155,
    # sparklint: disable=fsdp-profile-gate -- intentional annotation-only: TP-SP behavior without fsdp=True is pinned by test_sharding_rules
    sharding_profile="fsdp",  # scale annotation: perf iteration 6 measured
                              # collective 3.09s->0.61s, MFU 10.6%->54.2%
                              # under the launcher's ZeRO-3 hillclimb override;
                              # without fsdp=True the rule engine keeps TP-SP
                              # (distributed/sharding.py profile gate)
    notes="GQA dense decoder [hf:ibm-granite/granite-3.0-2b-base; hf]. "
          "vocab 49155 is padded to a multiple of the model axis by the "
          "sharding rules.",
)
SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=251, head_dim=0)
