"""Architecture configs (assigned pool) + input-shape registry.

Every arch is selectable via ``--arch <id>``; ``smoke_config(id)`` returns the
reduced same-family variant used by CPU smoke tests. The FULL configs are only
ever lowered via ShapeDtypeStructs in the dry-run (never allocated).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    group_size: int = 512


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: int


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_inner: int
    ssm_state: int = 16
    conv_kernel: int = 4
    dt_rank: int = 0  # 0 → ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads
    qk_norm: bool = False
    causal: bool = True
    attn_window: Optional[int] = None
    block_pattern: Tuple[str, ...] = ("attn",)   # cycled over layers
    moe: Optional[MoEConfig] = None
    rglru: Optional[RGLRUConfig] = None
    mamba: Optional[MambaConfig] = None
    frontend: Optional[str] = None   # vision | audio (stub: embeds in, not ids)
    mlp_type: str = "gated_silu"
    dropout_rate: float = 0.0
    dtype: Any = jnp.bfloat16
    fsdp: bool = False               # shard params over data axis too (ZeRO-3)
    sharding_profile: str = "tp_sp"  # "tp_sp": TP over model + sequence-
                                     #   parallel residuals (Megatron-style)
                                     # "fsdp": no TP — batch and params shard
                                     #   over (data×model) jointly (ZeRO-3);
                                     #   collective traffic scales with
                                     #   weights, not activations
    ctx_parallel_attn: bool = False  # shard attention *queries* over the
                                     # model axis when heads don't divide it
                                     # (context parallelism — removes the
                                     # 16× attention-compute replication)
    remat: bool = True
    scan_layers: bool = True         # False: unroll the layer stack (used by
                                     # the dry-run cost pass — XLA cost
                                     # analysis counts scan bodies once)
    sub_quadratic: bool = False      # eligible for long_500k decode
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.mamba is not None and self.mamba.dt_rank == 0:
            object.__setattr__(self, "mamba", dataclasses.replace(
                self.mamba, dt_rank=-(-self.d_model // 16)))

    @property
    def has_decode(self) -> bool:
        return self.causal  # encoder-only archs have no autoregressive step

    def param_count(self) -> int:
        """Approximate N for MODEL_FLOPS (embedding included once)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d * 2  # embed + head (untied)
        att = d * self.num_heads * self.head_dim * 2 \
            + d * self.num_kv_heads * self.head_dim * 2
        mlp = 3 * d * self.d_ff if self.mlp_type == "gated_silu" \
            else 2 * d * self.d_ff
        if self.moe:
            m = self.moe
            mlp = m.num_experts * 3 * d * m.d_ff_expert + d * m.num_experts \
                + m.num_shared_experts * 3 * d * m.d_ff_expert
        rec = 0
        if self.rglru:
            dr = self.rglru.d_rnn
            rec = 3 * d * dr + 2 * dr * dr + 4 * dr
        if self.mamba:
            mc = self.mamba
            rec = 3 * d * mc.d_inner + mc.d_inner * (
                2 * mc.ssm_state + 2 * mc.dt_rank + mc.ssm_state)
        total = 0
        for i in range(L):
            kind = self.block_pattern[i % len(self.block_pattern)]
            total += {"attn": att + mlp, "rec": rec + mlp,
                      "ssm": rec}[kind] + 2 * d
        return n + total

    def active_param_count(self) -> int:
        """N_active for MoE MODEL_FLOPS."""
        if not self.moe:
            return self.param_count()
        m = self.moe
        d, L = self.d_model, self.num_layers
        att = d * self.num_heads * self.head_dim * 2 \
            + d * self.num_kv_heads * self.head_dim * 2
        mlp_active = (m.top_k + m.num_shared_experts) * 3 * d * m.d_ff_expert
        return (self.vocab_size * d * 2
                + L * (att + mlp_active + d * m.num_experts + 2 * d))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCHS = [
    "llava_next_34b", "granite_3_2b", "qwen3_14b", "deepseek_67b",
    "deepseek_coder_33b", "hubert_xlarge", "dbrx_132b", "deepseek_moe_16b",
    "recurrentgemma_2b", "falcon_mamba_7b",
]


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG


def smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.SMOKE


def cells(arch: ArchConfig):
    """The (shape → runnable?) map for one arch, with skip reasons."""
    out = {}
    for sname, sh in SHAPES.items():
        if sh.kind == "decode" and not arch.has_decode:
            out[sname] = (False, "encoder-only: no autoregressive decode step")
        elif sname == "long_500k" and not arch.sub_quadratic:
            out[sname] = (False, "pure full-attention arch: 500k decode "
                                 "assigned to SSM/hybrid archs only")
        else:
            out[sname] = (True, "")
    return out
