"""hubert-xlarge — assigned architecture config (see configs/__init__ for fields)."""

import dataclasses

from repro.configs import ArchConfig, MoEConfig, RGLRUConfig, MambaConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="encoder",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    causal=False,                 # encoder-only: no decode shapes
    # sparklint: disable=fsdp-profile-gate -- intentional annotation-only: TP-SP behavior without fsdp=True is pinned by test_sharding_rules
    sharding_profile="fsdp",      # scale annotation (perf iteration 6:
                                  # 5.4x train step under the ZeRO-3 override);
                                  # engine keeps TP-SP without fsdp=True
    frontend="audio",             # frame embeddings provided by the stub
    mlp_type="gelu",
    notes="encoder-only audio backbone, w2v2 arch [arXiv:2106.07447; "
          "unverified]. head_dim=80. Loss = masked-unit CE over 504 units.",
)
SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=63, head_dim=0)
