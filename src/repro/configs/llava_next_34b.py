"""llava-next-34b — assigned architecture config (see configs/__init__ for fields)."""

import dataclasses

from repro.configs import ArchConfig, MoEConfig, RGLRUConfig, MambaConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    frontend="vision",   # anyres patch embeddings provided by the stub frontend
    fsdp=True,
    ctx_parallel_attn=True,  # 56 heads do not divide the 16-way model axis
                             # (+8x prefill compute - perf iteration 4)
    notes="decoder LM backbone of LLaVA-NeXT-34B (anyres tiling handled by the "
          "vision stub; input_specs() provides precomputed patch embeddings) "
          "[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]",
)
SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=0, fsdp=False)
