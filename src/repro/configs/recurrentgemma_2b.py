"""recurrentgemma-2b — assigned architecture config (see configs/__init__ for fields)."""

import dataclasses

from repro.configs import ArchConfig, MoEConfig, RGLRUConfig, MambaConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    attn_window=2048,                      # local attention
    block_pattern=("rec", "rec", "attn"),  # Griffin 2:1 pattern
    rglru=RGLRUConfig(d_rnn=2560),
    sub_quadratic=True,
    ctx_parallel_attn=True,  # 10 heads vs 16-way axis
    notes="RG-LRU + local attn 1:2 [arXiv:2402.19427; hf]. 10 heads do not "
          "divide the 16-way model axis -> attention params replicated, "
          "activations batch-sharded (sharding-rule fallback).",
)
SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=2, num_kv_heads=1,
    d_ff=128, vocab_size=256, head_dim=32, attn_window=32,
    rglru=RGLRUConfig(d_rnn=64))
