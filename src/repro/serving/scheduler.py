"""Continuous-batching scheduler over the paged KV cache.

Requests arrive with a prompt and a generation budget; between decode steps
the engine asks the scheduler to (a) evict finished sequences — returning
their pages to the pool — and (b) admit waiting ones FCFS while both a free
decode slot and the sequence's *full* page budget (prompt + generation,
reserved up front by :class:`BlockTables`) are available.  Admission stops at
the first request that doesn't fit, preserving arrival order; nothing is ever
preempted mid-generation, so no re-prefill path is needed.

The scheduler is pure host-side state — it never touches device arrays.  The
engine turns admissions into packed prefill calls and the active set into the
per-step ``block_tables``/``kv_len`` arrays.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serving.paged_cache import BlockTables, PagedCacheConfig


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # [prompt_len] int32
    max_new_tokens: int
    eos_id: Optional[int] = None  # finish early when this token is emitted
                                  # (None: run to the max_new_tokens budget)

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def budget_tokens(self) -> int:
        # KV writes over the lifetime: the prompt plus every decode-step input
        # token (prompt + max_new - 1); reserve one spare to keep the math
        # obviously safe.
        return self.prompt_len + self.max_new_tokens


@dataclasses.dataclass
class ActiveSeq:
    request: Request
    slot: int
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        """Budget exhausted, or EOS emitted (freeing the slot and its pages
        immediately instead of decoding dead tokens to the budget)."""
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return (eos is not None and bool(self.generated)
                and self.generated[-1] == eos)


class Scheduler:
    def __init__(self, cfg: PagedCacheConfig):
        self.cfg = cfg
        self.tables = BlockTables(cfg)
        self.waiting: Deque[Request] = collections.deque()
        self.active: Dict[int, ActiveSeq] = {}    # slot → sequence
        self.finished: List[ActiveSeq] = []

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.active

    def submit(self, req: Request):
        if req.budget_tokens > self.cfg.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+generation of {req.budget_tokens} "
                f"tokens can never fit max_seq_len={self.cfg.max_seq_len}")
        self.waiting.append(req)

    def evict_finished(self) -> List[ActiveSeq]:
        done = [seq for seq in self.active.values() if seq.done]
        for seq in done:
            del self.active[seq.slot]
            self.tables.release(seq.slot)
            self.finished.append(seq)
        return done

    def admit(self) -> List[ActiveSeq]:
        """FCFS admission: free slot + full page budget, else stop."""
        admitted = []
        free = self.tables.free_slots()
        while self.waiting and free:
            req = self.waiting[0]
            slot = free[0]
            if not self.tables.admit(slot, req.budget_tokens):
                break  # pool exhausted — keep arrival order, wait for evictions
            self.waiting.popleft()
            free.pop(0)
            seq = ActiveSeq(request=req, slot=slot)
            self.active[slot] = seq
            admitted.append(seq)
        return admitted
