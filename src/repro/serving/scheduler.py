"""Continuous-batching scheduler over the paged KV cache.

Requests arrive with a prompt and a generation budget; between decode steps
the engine asks the scheduler to (a) evict finished sequences — returning
their pages to the pool — (b) reclaim pages that slid out of a sliding
attention window, (c) grow every running sequence's next write page, and
(d) admit waiting requests FCFS while a free decode slot and their admission
page budget are available.  Two admission policies share the machinery:

* **eager** (default) — admission reserves the *full* lifetime budget
  (``prompt + max_new`` pages) up front, so growth is a no-op and a running
  batch can never run dry; utilization pays for the guarantee.
* **lazy** (``lazy=True``) — admission reserves only the *prompt* pages and
  decode pages are allocated one at a time as ``kv_len`` crosses page
  boundaries.  When the pool runs dry mid-growth, the scheduler **preempts
  the youngest running sequence**: its pages are freed and it re-queues at
  the *front* of the waiting line with its generated tokens appended to the
  prompt, to be **re-prefilled** later.  Greedy decode makes the resumed
  generation token-identical to an unpreempted run (tests assert it).

The state machine (docs/scheduling.md has the full picture)::

    WAITING --admit--> ACTIVE --done/EOS--> FINISHED
       ^                  |
       +---- preempt -----+   (lazy only: growth failed → the youngest row
                               is re-queued at the front of WAITING with
                               prompt := prompt + generated)

The scheduler is pure host-side state — it never touches device arrays.  The
engine turns admissions into packed prefill calls and the active set into the
per-step ``block_tables``/``kv_len`` arrays; preemption/growth/reclamation
only rewrite those host arrays, so the fixed-shape jitted steps never
recompile.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serving.paged_cache import BlockTables, PagedCacheConfig


class AdmissionImpossible(ValueError):
    """A request whose worst-case footprint can never fit this pool.

    Subclasses ``ValueError`` so callers treating capacity rejection as
    malformed input keep working, while the engine can catch it
    specifically and shed the request with a typed ``SHED`` outcome
    instead of propagating an exception."""


@dataclasses.dataclass
class Request:
    """One serving request (or the resumed tail of a preempted one)."""
    rid: int
    tokens: np.ndarray            # [prompt_len] int32
    max_new_tokens: int
    eos_id: Optional[int] = None  # finish early when this token is emitted
                                  # (None: run to the max_new_tokens budget)
    generated_prefix: List[int] = dataclasses.field(default_factory=list)
    # tokens generated before a preemption: they ride along in ``tokens`` for
    # the re-prefill, and the engine stitches them back onto the output

    @property
    def prompt_len(self) -> int:
        """Tokens the next prefill must process (original prompt, plus any
        generated-so-far carried across a preemption)."""
        return int(self.tokens.shape[0])

    @property
    def budget_tokens(self) -> int:
        """KV writes over the remaining lifetime: the prompt plus every
        decode-step input token (prompt + max_new - 1); one spare keeps the
        math obviously safe.  Invariant under preemption — the resumed
        request's longer prompt and smaller budget sum to the same total."""
        return self.prompt_len + self.max_new_tokens


@dataclasses.dataclass
class ActiveSeq:
    """A request bound to a decode slot, plus its generation state."""
    request: Request
    slot: int
    birth: int = 0                # admission stamp: preemption evicts max
    generated: List[int] = dataclasses.field(default_factory=list)
    prefilled: int = 0            # prompt tokens resident in pages so far
    # (admission seeds it with the prefix-cache hit length; chunked prefill
    # advances it one budgeted span at a time until it hits prompt_len)

    @property
    def prefilling(self) -> bool:
        """Still mid-prompt: excluded from decode steps, fed to the chunked
        prefill until ``prefilled`` reaches the prompt length."""
        return self.prefilled < self.request.prompt_len

    @property
    def done(self) -> bool:
        """Budget exhausted, or EOS emitted (freeing the slot and its pages
        immediately instead of decoding dead tokens to the budget)."""
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return (eos is not None and bool(self.generated)
                and self.generated[-1] == eos)

    @property
    def all_generated(self) -> List[int]:
        """Full generation including tokens from before any preemption."""
        return self.request.generated_prefix + self.generated


class Scheduler:
    """Admission / growth / preemption / eviction over one page pool."""

    def __init__(self, cfg: PagedCacheConfig, *, lazy: bool = False,
                 window: Optional[int] = None, share_prefix: bool = False,
                 chunked: bool = False):
        """window: the sliding attention window when page reclamation is on
        (None otherwise).  Lazy admission uses it to skip blocks that are
        dead on arrival — a preempted long-tail row resumes by reserving
        only its O(window) live tail instead of the whole prefix.
        share_prefix: content-addressed prefix caching — admission aliases
        matched prompt blocks onto existing physical pages (skipping their
        prefill compute) and divergent writes copy-on-write.
        chunked: the engine splits prompts into prefill chunks — like
        share_prefix, this makes prefill read *cached* history instead of
        in-row activations, which disables the dead-on-arrival block skip
        (see :meth:`_first_live_block`)."""
        self.cfg = cfg
        self.lazy = lazy
        self.window = window
        self.share_prefix = share_prefix
        self.chunked = chunked
        self.tables = BlockTables(cfg, share_prefix=share_prefix)
        self.waiting: Deque[Request] = collections.deque()
        self.active: Dict[int, ActiveSeq] = {}    # slot → sequence
        self.finished: List[ActiveSeq] = []
        self.preemptions = 0
        self.prefill_skipped = 0   # prompt tokens served by prefix hits
        self._births = 0
        self._rids: set = set()    # every rid ever submitted (dup guard)

    @property
    def idle(self) -> bool:
        """Nothing waiting and nothing running — the serve loop's exit."""
        return not self.waiting and not self.active

    def submit(self, req: Request):
        """Queue a request; rejects ones that could never be admitted —
        empty prompts, duplicate rids (two requests with the same rid would
        silently drop one generation from the keyed output), and budgets the
        block tables or the page pool can never cover (a too-big request
        would otherwise sit at the queue head and deadlock the serve loop)."""
        if req.prompt_len < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.rid in self._rids:
            raise ValueError(
                f"request rid {req.rid} is already submitted — rids key the "
                f"output dict, a duplicate would drop one generation")
        if req.budget_tokens > self.cfg.max_seq_len:
            raise AdmissionImpossible(
                f"request {req.rid}: prompt+generation of {req.budget_tokens} "
                f"tokens can never fit max_seq_len={self.cfg.max_seq_len}")
        if self.peak_pages(req) > self.cfg.usable_pages:
            raise AdmissionImpossible(
                f"request {req.rid} needs more pages than the pool holds "
                f"({self.peak_pages(req)} > {self.cfg.usable_pages} usable)")
        self._rids.add(req.rid)
        self.waiting.append(req)

    def peak_pages(self, req: Request) -> int:
        """Worst-case simultaneous page footprint of a request on this
        scheduler — the submit-time shedding bound.

        The naive bound is ``pages_for(budget_tokens)``: every position the
        lifetime writes gets a page.  Under a sliding window with lazy
        admission (and neither prefix sharing nor chunked prefill, which
        re-enable whole-prefix residency — see :meth:`_first_live_block`),
        dead-on-arrival blocks go to trash at admission and reclamation
        frees blocks as they slide out, so a row only ever holds its
        O(window) live tail: ``pages_for(window)`` plus one straddle page
        and one not-yet-reclaimed page.  Without this relaxation a long
        request on a small windowed pool sheds at submit even though the
        pool could serve it forever — and with the *old* token-count-only
        check such a request was accepted and then spun in the admission
        queue without ever fitting."""
        full = self.cfg.pages_for(req.budget_tokens)
        if self.lazy and self.window is not None \
                and not self.share_prefix and not self.chunked:
            return min(full, self.cfg.pages_for(self.window) + 2)
        return full

    def remove_waiting(self, rid: int) -> Optional[Request]:
        """Pull a request out of the waiting queue by rid (cancellation,
        deadline expiry, watchdog shedding).  Returns it, or None if no
        waiting request has that rid.  The rid stays burned in the dup
        guard — a terminated request must not be resubmittable under the
        same key."""
        for req in self.waiting:
            if req.rid == rid:
                self.waiting.remove(req)
                return req
        return None

    def evict_finished(self) -> List[ActiveSeq]:
        """Move done sequences to ``finished``, returning their pages."""
        done = [seq for seq in self.active.values() if seq.done]
        for seq in done:
            del self.active[seq.slot]
            self.tables.release(seq.slot)
            self.finished.append(seq)
        return done

    def reclaim(self, window: int) -> List[int]:
        """Free every active row's fully-out-of-window pages (sliding-window
        archs only); returns the freed page ids so the engine can poison them
        under test.  Valid in both admission modes — reclaimed blocks sit
        strictly below the write block, so eager's no-growth invariant holds."""
        freed: List[int] = []
        for slot in list(self.active):
            freed.extend(self.tables.reclaim_out_of_window(slot, window))
        return freed

    def preempt(self, seq: ActiveSeq):
        """Free a running sequence's pages and re-queue it for re-prefill.

        The resumed request carries the original prompt *plus* everything
        generated so far as its new prompt (greedy decode: re-prefilling the
        full prefix reproduces the next token exactly), keeps the rid/EOS,
        and shrinks the budget by what it already produced.  It goes to the
        *front* of the waiting line: running work outranks new arrivals.
        """
        del self.active[seq.slot]
        self.tables.release(seq.slot)
        req = seq.request
        self.waiting.appendleft(Request(
            rid=req.rid,
            tokens=np.concatenate(
                [req.tokens, np.asarray(seq.generated, np.int32)]),
            max_new_tokens=req.max_new_tokens - len(seq.generated),
            eos_id=req.eos_id,
            generated_prefix=req.generated_prefix + list(seq.generated)))
        self.preemptions += 1

    def ensure_growth(self, n: int = 1) -> List[int]:
        """Guarantee every surviving active row owns its next ``n`` write
        pages' worth of positions (``n = 1``: plain decode; speculative
        decode passes ``k + 1`` so one verify step can scatter a whole
        draft, growing across page boundaries when the draft straddles one).

        Oldest rows grow first; when the pool is dry the *youngest* active
        sequence is preempted and the allocation retried — each preemption
        strictly shrinks the active set, so the loop terminates even when a
        victim's pages were all shared (freeing them only drops refcounts).
        If the youngest is the row being grown, it preempts itself; its
        resumed prompt needs one page more than it just freed, which the
        submit-time check (budget pages <= usable pages) guarantees the pool
        can supply once it is the admission front-runner — each such cycle
        still moves at least one generated token into the prefix, so it
        cannot loop forever.  Under prefix sharing this pass also performs
        the copy-on-write step: a row whose write block sits on a shared
        page moves to a fresh page here (the engine applies the queued
        device copies right after).  Returns the preempted rids.  Eager
        mode owns every budget page up front, so growth is a no-op there
        (COW is not — with sharing on, even eager can preempt here).

        The lookahead is capped per row at the tokens it can still write
        before finishing (a nearly-done row must not reserve pages past its
        budget — they could never be used and would shrink everyone else's
        pool) and drops to 1 for mid-prefill rows, whose prompt pages were
        all reserved at admission.
        """
        preempted: List[int] = []
        for seq in sorted(self.active.values(), key=lambda s: s.birth):
            if self.active.get(seq.slot) is not seq:
                continue               # already preempted by an older row
            n_row = 1 if seq.prefilling else max(1, min(
                n, seq.request.max_new_tokens - len(seq.generated)))
            while not self.tables.prepare_write(seq.slot, n_row):
                victim = max(self.active.values(), key=lambda s: s.birth)
                self.preempt(victim)
                preempted.append(victim.request.rid)
                if victim is seq:
                    break              # self-preempted: nothing left to grow
        return preempted

    def _first_live_block(self, prompt_len: int) -> int:
        """Blocks already dead at admission under a sliding window: at the
        first post-prefill decode the query sits at ``prompt_len``, so a
        block whose last position ``(blk+1)·ps - 1 <= prompt_len - window``
        is out of the window before it is ever read (the same horizon
        ``reclaim`` uses).  Prefill attention reads the in-row activations,
        not the cache, so those blocks' writes can go straight to trash.

        That justification only holds for whole-prompt in-row prefill:
        chunked and prefix-hit suffix spans attend through the *cache*, and
        a suffix query just above the skipped region still reaches into it
        (its window spans positions below ``prompt_len - window``), so with
        sharing or chunking enabled every prompt block gets a real page."""
        if not self.lazy or self.window is None \
                or self.share_prefix or self.chunked:
            return 0
        ps = self.cfg.page_size
        n_blocks = self.cfg.pages_for(prompt_len)
        dead = max(0, (prompt_len - self.window + 1) // ps)
        return min(dead, n_blocks - 1)   # the last block is always live

    def admit(self) -> List[ActiveSeq]:
        """FCFS admission: free slot + the admission page budget (full
        lifetime when eager, prompt-only when lazy, minus any blocks a
        sliding window already killed), else stop — arrival order is
        preserved, and preempted requests re-enter from the front."""
        admitted = []
        free = self.tables.free_slots()
        while self.waiting and free:
            req = self.waiting[0]
            slot = free[0]
            need = req.prompt_len if self.lazy else req.budget_tokens
            if not self.tables.admit(slot, need,
                                     self._first_live_block(req.prompt_len),
                                     tokens=req.tokens):
                break  # pool exhausted — keep arrival order, wait for pages
            self.waiting.popleft()
            free.pop(0)
            hist = self.tables.hist.get(slot, 0)
            seq = ActiveSeq(request=req, slot=slot, birth=self._births,
                            prefilled=hist)
            self.prefill_skipped += hist
            self._births += 1
            self.active[slot] = seq
            admitted.append(seq)
        return admitted
