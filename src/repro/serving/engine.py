"""Continuous-batching serving engine over the paged KV cache.

Ties the pieces together: the scheduler admits/evicts between decode steps,
admissions are packed into fused prefill rows (segment-aware: one forward
fills every admitted prompt's pages), and the decode step runs all active
slots against the page pool via block tables.  Greedy sampling; a request
finishes when it emits its ``eos_id`` (set per request or engine-wide) or
exhausts ``max_new_tokens`` — EOS eviction frees the slot and pages
immediately instead of decoding dead tokens to the budget.

The jitted steps see fixed shapes only — [B=max_batch] decode rows, packed
prefill rows of ``prefill_len`` — so the whole ragged, churning workload runs
on exactly two compilations.

Distributed serving: pass ``mesh=`` (with ``PagedCacheConfig.num_shards`` =
the mesh's model-axis size) and the page pools shard page-aligned over the
mesh while decode runs per-shard local attention + online-softmax partial
merge (distributed/paged.py). The host-side scheduler/allocator logic is
byte-identical in both modes — block tables keep global page ids.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.steps import make_serve_steps
from repro.serving.paged_cache import PagedCacheConfig
from repro.serving.scheduler import ActiveSeq, Request, Scheduler


class ServingEngine:
    def __init__(self, cfg, paged_cfg: PagedCacheConfig, params, *,
                 impl: str = "xla", prefill_len: Optional[int] = None,
                 xla_chunk: int = 1024, mesh=None,
                 eos_id: Optional[int] = None):
        assert cfg.causal, "serving needs an autoregressive arch"
        self.cfg = cfg
        self.pcfg = paged_cfg
        self.prefill_len = prefill_len or paged_cfg.max_seq_len
        self.eos_id = eos_id                     # default for submissions
        arts = make_serve_steps(cfg, mesh=mesh, impl=impl, paged=paged_cfg,
                                xla_chunk=min(xla_chunk, self.prefill_len))
        if mesh is not None and arts.rules is not None:
            # lay the params out per the serve rules (specs are structural —
            # non-divisible dims such as an unpadded vocab fall back to
            # replication automatically)
            from repro.models import lm
            _, specs = lm.abstract_params(cfg, vocab_pad_to=1)
            params = jax.device_put(params,
                                    arts.rules.tree_shardings(params, specs))
        self.params = params
        self.prefill_fn = arts.prefill_fn
        self.decode_fn = arts.decode_fn
        self.caches = arts.cache_init_fn()
        self.scheduler = Scheduler(paged_cfg)
        self.util_samples: List[float] = []
        self._next_rid = 0

    # -- request intake ----------------------------------------------------
    def submit(self, tokens, max_new_tokens: int, rid: Optional[int] = None,
               eos_id: Optional[int] = None):
        tokens = np.asarray(tokens, np.int32)
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid + 1)
        req = Request(rid=rid, tokens=tokens, max_new_tokens=max_new_tokens,
                      eos_id=self.eos_id if eos_id is None else eos_id)
        if req.prompt_len < 1:
            raise ValueError(f"request {rid}: empty prompt")
        if req.prompt_len > self.prefill_len:
            raise ValueError(f"prompt of {req.prompt_len} tokens exceeds "
                             f"prefill_len={self.prefill_len}")
        if self.pcfg.pages_for(req.budget_tokens) > self.pcfg.usable_pages:
            raise ValueError(f"request {rid} needs more pages than the pool "
                             f"holds ({self.pcfg.usable_pages} usable)")
        self.scheduler.submit(req)
        return rid

    # -- one packed prefill wave -------------------------------------------
    def _pack_rows(self, seqs: List[ActiveSeq]) -> List[List[ActiveSeq]]:
        rows: List[List[ActiveSeq]] = [[]]
        used = 0
        for seq in seqs:  # first-fit in admission order
            n = seq.request.prompt_len
            if used + n > self.prefill_len:
                rows.append([])
                used = 0
            rows[-1].append(seq)
            used += n
        return rows

    def _prefill(self, seqs: List[ActiveSeq]):
        tables = self.scheduler.tables
        for row in self._pack_rows(seqs):
            tokens = np.zeros((1, self.prefill_len), np.int32)
            seg = np.full((1, self.prefill_len), -1, np.int32)
            pos = np.zeros((1, self.prefill_len), np.int32)
            off = 0
            last_idx = []
            for i, seq in enumerate(row):
                n = seq.request.prompt_len
                tokens[0, off:off + n] = seq.request.tokens
                seg[0, off:off + n] = i
                pos[0, off:off + n] = np.arange(n)
                last_idx.append(off + n - 1)
                off += n
            dest = tables.prefill_dest(seg[0], [s.slot for s in row])
            logits, self.caches = self.prefill_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(seg),
                jnp.asarray(pos), jnp.asarray(dest[None]), self.caches)
            logits = np.asarray(logits[0, :, :self.cfg.vocab_size])
            for seq, li in zip(row, last_idx):
                tables.kv_len[seq.slot] = seq.request.prompt_len
                seq.generated.append(int(logits[li].argmax()))

    # -- one decode step over every active slot ----------------------------
    def _decode(self):
        sched = self.scheduler
        tables = sched.tables
        tok = np.zeros((self.pcfg.max_batch,), np.int32)
        for slot, seq in sched.active.items():
            assert tables.append_dest_ok(slot), \
                f"slot {slot}: write position escaped its reserved pages"
            tok[slot] = seq.generated[-1]
        logits, self.caches = self.decode_fn(
            self.params, jnp.asarray(tok), self.caches,
            jnp.asarray(tables.tables), jnp.asarray(tables.kv_len))
        logits = np.asarray(logits[:, :self.cfg.vocab_size])
        for slot, seq in sched.active.items():
            tables.kv_len[slot] += 1
            seq.generated.append(int(logits[slot].argmax()))

    # -- the serving loop ---------------------------------------------------
    def run(self, requests: Optional[List[Tuple[np.ndarray, int]]] = None
            ) -> Tuple[Dict[int, np.ndarray], Dict[str, float]]:
        """Serve until the queue drains. requests: (prompt_tokens, max_new)
        pairs to submit first. Returns ({rid: generated tokens}, stats)."""
        for tokens, max_new in requests or []:
            self.submit(tokens, max_new)
        sched = self.scheduler
        t0 = time.perf_counter()
        steps = 0
        while not sched.idle:
            sched.evict_finished()
            admitted = sched.admit()
            if admitted:
                self._prefill(admitted)
                sched.evict_finished()     # max_new == 1 finishes at prefill
            if sched.active:
                self.util_samples.append(
                    sched.tables.utilization()["utilization"])
                self._decode()
                steps += 1
            elif sched.waiting and not admitted:
                # an admitted wave may finish entirely at prefill
                # (max_new == 1); that's progress, not a deadlock
                raise RuntimeError(
                    "scheduler stuck: nothing active yet nothing admissible "
                    "— the page pool is too small for the waiting requests")
        wall = time.perf_counter() - t0
        out = {seq.request.rid: np.asarray(seq.generated, np.int32)
               for seq in sched.finished}
        n_tok = sum(len(g) for g in out.values())
        stats = {
            "wall_s": wall,
            "decode_steps": float(steps),
            "generated_tokens": float(n_tok),
            "tokens_per_s": n_tok / max(wall, 1e-9),
            "mean_utilization": (float(np.mean(self.util_samples))
                                 if self.util_samples else 0.0),
        }
        return out, stats
