"""Continuous-batching serving engine over the paged KV cache.

Ties the pieces together: between decode steps the scheduler evicts finished
sequences, reclaims pages that slid out of a sliding attention window, grows
every running sequence's next write page (lazy mode — preempting the youngest
row when the pool runs dry), and admits waiting requests; admissions are
packed into fused prefill rows (segment-aware: one forward fills every
admitted prompt's pages), and the decode step runs all active slots against
the page pool via block tables.  Greedy sampling; a request finishes when it
emits its ``eos_id`` (set per request or engine-wide) or exhausts
``max_new_tokens`` — EOS eviction frees the slot and pages immediately.

Admission policy (``lazy=``): eager reserves a sequence's full page budget up
front and never preempts on growth; lazy reserves only the prompt pages,
grows decode pages one at a time, and re-prefills preempted rows with their
generated tokens appended to the prompt — token-identical to eager under
greedy decode (tests assert it), at strictly higher pool utilization.  The
state machine and its invariants are documented in docs/scheduling.md.

Prefix caching (``share_prefix=``): admission matches each prompt's
page-aligned blocks against a content-addressed index and aliases matched
blocks onto the existing physical pages — those tokens skip both page
allocation and prefill compute; the first divergent write to a shared page
copy-on-writes it (the scheduler queues the device page copy, applied here
before the next step).  Finished/preempted sequences park their indexed
pages in a cached LRU ring, so later identical prefixes revive them for
free.  Generations are bit-identical to the unshared engine: identical
prefixes at identical positions have identical K/V, and COW isolates every
divergence (with sharing on, even eager admission can preempt when a COW
allocation finds the pool dry).

Chunked prefill (``prefill_chunk=``): prompts are prefilled in spans of at
most that many tokens per engine iteration, interleaved with decode steps —
one long prompt no longer stalls the whole batch.  A span scatters its K/V
into the pages first and then attends per-token through its own block-table
row (``chunk_prefill_fn``), which also serves prefix-cache hits: a matched
prompt just prefills its unmatched suffix the same way.  Greedy decode makes
chunked runs token-identical to unchunked ones.

Speculative decoding (``speculate_k=``): between steps a prompt-lookup
drafter (serving/drafter.py) proposes up to ``k`` continuation tokens per
decode row from its own token history; the decode step is then replaced by a
verify step that scores all ``k + 1`` positions (current token + drafts) in
one model call through the same per-token paged-attention primitive chunked
prefill uses.  The longest draft prefix matching the model's own greedy
argmaxes is accepted — plus the model's token at the first mismatch — so
each verify call emits 1 to ``k + 1`` tokens and advances ``kv_len`` by as
many, growing/COW-ing every page the multi-token write touches *before* the
step (``ensure_growth(k + 1)``).  Rejected drafts' K/V writes are rolled
back logically: they sit at positions ``>= kv_len``, which every kernel read
gates out, and the next verify re-scatters those positions before ``kv_len``
ever covers them.  Greedy acceptance makes the generation token-identical to
plain single-step decode by construction (the composition matrix in
tests/test_speculative.py pins this across every serving feature).

Hybrid SSM/recurrent archs (mamba, rgLRU — falcon_mamba, recurrentgemma):
every recurrent layer keeps one fixed state row per decode slot (plus a
trailing trash row), admitted/released by the same scheduler calls that
bind a slot's pages (serving/state_cache.py).  Prefill spans route through
per-token ``state_slots``/``state_local`` — the packed scan resets at span
starts, a chunked continuation resumes the slot's stored state, and span-end
state scatters back to the row; decode updates rows gated on ``kv_len > 0``
so masked and inactive slots never move.  Correctness never reads a released
row: a re-admitted slot's first span starts at position 0, which injects a
fresh zero state (``poison_reclaimed`` clobbers released rows to prove it).
Preempted rows re-prefill prompt+generated from position 0, exactly like the
attention path.  Prefix sharing and speculation are attention-only (the
index certifies KV pages, not state; cumulative state cannot roll back) and
raise on recurrent archs.  MoE archs serve unchanged — expert routing is
stateless per token.

The jitted steps see fixed shapes only — [B=max_batch] decode rows, packed
prefill rows of ``prefill_len``, [B, k+1] verify rows — so the whole ragged,
churning workload runs on a handful of compilations; growth/preemption/
reclamation rewrite nothing but the tiny host-side block-table arrays
re-shipped each step.

Distributed serving: pass ``mesh=`` (with ``PagedCacheConfig.num_shards`` =
the mesh's model-axis size) and the page pools shard page-aligned over the
mesh while decode runs per-shard local attention + online-softmax partial
merge (distributed/paged.py). The host-side scheduler/allocator logic is
byte-identical in both modes — block tables keep global page ids, so every
shard sees the same post-growth/post-reclaim tables each step (per-shard
lockstep for free).
"""

from __future__ import annotations

import copy
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import tree_map, tree_map_with_path

from repro.models.layers import paged_decode_window
from repro.runtime.steps import make_serve_steps
from repro.serving.drafter import NgramDrafter, longest_accept
from repro.serving.faults import FaultPlan, InjectedCrash
from repro.serving.outcomes import Outcome, RequestResult, outcome_counts
from repro.serving.paged_cache import PagedCacheConfig, TRASH_PAGE
from repro.serving.scheduler import (AdmissionImpossible, ActiveSeq, Request,
                                     Scheduler)


def _map_pool_leaves(caches, fn):
    """Apply ``fn`` to the attention page-pool leaves (k_pages/v_pages)
    only.  Hybrid archs' recurrent-state leaves live in the same cache tree
    with a different layout — per-slot rows, not pages — so page-indexed
    ops (COW copies, reclaimed-page poisoning) must skip them."""
    def g(path, x):
        if getattr(path[-1], "key", None) in ("k_pages", "v_pages"):
            return fn(x)
        return x
    return tree_map_with_path(g, caches)


class ServingEngine:
    """The serving loop: scheduler decisions → the two jitted steps."""

    def __init__(self, cfg, paged_cfg: PagedCacheConfig, params, *,
                 impl: str = "xla", prefill_len: Optional[int] = None,
                 xla_chunk: int = 1024, mesh=None,
                 eos_id: Optional[int] = None, lazy: bool = False,
                 reclaim: Optional[bool] = None,
                 poison_reclaimed: bool = False,
                 num_splits: Optional[int] = None, autotune: bool = False,
                 share_prefix: bool = False,
                 prefill_chunk: Optional[int] = None,
                 speculate_k: Optional[int] = None,
                 deadline_ms: Optional[float] = None,
                 max_steps: Optional[int] = None,
                 max_queue: Optional[int] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 watchdog_patience: int = 16):
        """lazy: admission policy (module docstring). reclaim: free
        fully-out-of-window pages each step — defaults to "whenever the arch
        has a sliding window"; pass False to pin pages for a model's whole
        residency (the pre-reclamation behaviour, kept for A/B tests).
        poison_reclaimed: test hook — overwrite freed pages and the trash
        page with a huge constant, so any kernel read of a reclaimed page
        corrupts the output instead of passing silently.
        num_splits: split-KV decode grid cells per (batch, kv-head) — baked
        into the jitted decode step (default 1). autotune: pick num_splits
        from the perf/autotune.py cost model for this engine's geometry,
        through its persistent cache (an explicit num_splits wins).
        share_prefix: content-addressed prefix caching + copy-on-write pages
        (module docstring). prefill_chunk: max prompt tokens prefilled per
        engine iteration (None: whole prompts at once), interleaving long
        prompts with decode steps.
        speculate_k: draft up to this many tokens per decode row with the
        prompt-lookup drafter and verify them in one model call (module
        docstring); None/0 turns speculation off.  Token-identical to plain
        greedy decode under every admission/sharing/chunking mode.
        deadline_ms / max_steps: default per-request deadlines — wall-clock
        milliseconds and engine-iteration budget respectively; a request
        exceeding either terminates with a ``TIMEOUT`` outcome and its
        slot/pages/state reclaimed immediately (``submit`` takes per-request
        overrides).  None disables that limit.
        max_queue: bounded admission queue — submissions past this many
        waiting requests shed (reject-newest, typed ``SHED`` outcome)
        instead of queueing without bound.  None: unbounded (the batch-
        replay default).
        fault_plan: a seeded :class:`~repro.serving.faults.FaultPlan` whose
        events this engine applies at host-layer seams each iteration (the
        chaos harness); None serves faithfully.
        watchdog_patience: iterations with zero progress (no tokens, no
        prefill, no completions) the livelock watchdog tolerates before it
        fails a stuck row with a diagnostic ``FAILED`` outcome."""
        assert cfg.causal, "serving needs an autoregressive arch"
        self.cfg = cfg
        self.pcfg = paged_cfg
        self.prefill_len = prefill_len or paged_cfg.max_seq_len
        self.eos_id = eos_id                     # default for submissions
        self.lazy = lazy
        self.window = paged_decode_window(cfg)
        self.reclaim = (self.window is not None) if reclaim is None else reclaim
        if self.reclaim and self.window is None:
            raise ValueError("page reclamation needs a sliding-window arch "
                             "(cfg.attn_window is None)")
        self.poison_reclaimed = poison_reclaimed
        self.share_prefix = share_prefix
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be at least one token")
        self.prefill_chunk = prefill_chunk
        if num_splits is None:
            num_splits = self._autotuned_splits() if autotune else 1
        self.num_splits = num_splits
        if speculate_k is not None and speculate_k < 0:
            raise ValueError("speculate_k must be a non-negative draft width")
        self.has_state = any(k != "attn" for k in cfg.block_pattern)
        if self.has_state and share_prefix:
            raise ValueError(
                "prefix sharing is attention-only: the prefix index "
                "certifies cached KV pages, not recurrent state — a hit "
                "would skip the state computation a resumed scan needs")
        if self.has_state and speculate_k:
            raise ValueError(
                "speculative decoding is attention-only: recurrent state "
                "is cumulative, so rejected drafts cannot be rolled back "
                "logically the way out-of-kv_len page writes can")
        self.speculate_k = int(speculate_k or 0)
        self.drafter = (NgramDrafter(self.speculate_k)
                        if self.speculate_k else None)
        # with speculation on, a verify step can advance kv_len by up to
        # k + 1 tokens, so every growth pass reserves that many positions
        self._lookahead = self.speculate_k + 1
        arts = make_serve_steps(cfg, mesh=mesh, impl=impl, paged=paged_cfg,
                                num_splits=num_splits,
                                xla_chunk=min(xla_chunk, self.prefill_len))
        if mesh is not None and arts.rules is not None:
            # lay the params out per the serve rules (specs are structural —
            # non-divisible dims such as an unpadded vocab fall back to
            # replication automatically)
            from repro.models import lm
            _, specs = lm.abstract_params(cfg, vocab_pad_to=1)
            params = jax.device_put(params,
                                    arts.rules.tree_shardings(params, specs))
        self.params = params
        self.prefill_fn = arts.prefill_fn
        self.decode_fn = arts.decode_fn
        self.chunk_prefill_fn = arts.chunk_prefill_fn
        self.verify_fn = arts.verify_fn
        self.caches = arts.cache_init_fn()
        # the scheduler learns the window only when reclamation is on: with
        # reclaim=False pinned-pages runs keep the full-prefix reservation
        # so they reflect the pre-reclamation footprint faithfully
        self.scheduler = Scheduler(
            paged_cfg, lazy=lazy,
            window=self.window if self.reclaim else None,
            share_prefix=share_prefix,
            chunked=prefill_chunk is not None)
        self.util_samples: List[float] = []
        self.pool_samples: List[float] = []      # allocated / usable pages
        self.prefill_tokens = 0                  # prompt tokens run by prefill
        self.drafted_tokens = 0                  # draft tokens sent to verify
        self.accepted_tokens = 0                 # drafts the model agreed with
        self._next_rid = 0
        # -- resilience state (typed outcomes, deadlines, watchdog, faults) --
        self.deadline_ms = deadline_ms
        self.max_steps = max_steps
        self.max_queue = max_queue
        self.fault_plan = fault_plan
        self.watchdog_patience = watchdog_patience
        self.results: Dict[int, RequestResult] = {}   # rid → terminal record
        self.watchdog_fires = 0
        self.cancels = 0
        self._iter = 0            # engine iterations — the fault-plan clock
        self._steps = 0           # decode/verify steps (stats)
        self._stall = 0           # consecutive zero-progress iterations
        self._deadline_at: Dict[int, float] = {}   # rid → absolute wall time
        self._step_limit: Dict[int, int] = {}      # rid → absolute iteration
        self._nan_pending: List[int] = []          # fault args awaiting decode
        self._fault_pocket: List[Tuple[int, List[int]]] = []
        # (release-at iteration, pages) held by the "exhaust" fault

    def _autotuned_splits(self) -> int:
        """Pick the decode step's split count from the autotune cost model.

        The jitted step needs a *static* num_splits, so the plan targets the
        worst-case geometry this engine can see: every slot active at its
        full block-table reach. Plans memoise in the persistent autotune
        cache (``perf/autotune.py``), keyed by this exact geometry.
        """
        import jax.numpy as jnp_

        from repro.perf.autotune import DecodeShape, plan_decode_persistent
        shape = DecodeShape(
            batch=self.pcfg.max_batch,
            hkv=self.cfg.num_kv_heads,
            group=self.cfg.num_heads // self.cfg.num_kv_heads,
            kv_len=self.pcfg.max_pages_per_seq * self.pcfg.page_size,
            head_dim=self.cfg.head_dim,
            page_size=self.pcfg.page_size,
            dtype_bytes=jnp_.dtype(self.cfg.dtype).itemsize)
        return plan_decode_persistent(shape).num_splits

    # -- request intake ----------------------------------------------------
    def submit(self, tokens, max_new_tokens: int, rid: Optional[int] = None,
               eos_id: Optional[int] = None,
               deadline_ms: Optional[float] = None,
               max_steps: Optional[int] = None):
        """Queue one request; validates it can ever be served.

        Malformed requests (empty prompt, duplicate rid, prompts wider than
        a prefill row) still raise — those are caller bugs.  *Capacity*
        rejections are load conditions, not bugs, so they shed instead: a
        full admission queue (``max_queue``) or an impossible page footprint
        (:class:`~repro.serving.scheduler.AdmissionImpossible`) records a
        typed ``SHED`` outcome and returns the rid without queueing.
        deadline_ms / max_steps override the engine-wide defaults for this
        request."""
        tokens = np.asarray(tokens, np.int32)
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid + 1)
        if rid in self.results:
            raise ValueError(
                f"request rid {rid} is already submitted — rids key the "
                f"output dict, a duplicate would drop one generation")
        req = Request(rid=rid, tokens=tokens, max_new_tokens=max_new_tokens,
                      eos_id=self.eos_id if eos_id is None else eos_id)
        # prefill-row-width checks live here (the scheduler doesn't know the
        # engine's prefill_len); empty-prompt / duplicate-rid / pool-capacity
        # validation lives in Scheduler.submit so direct scheduler users get
        # the same guarantees
        if req.prompt_len > self.prefill_len \
                and not (self.share_prefix or self.prefill_chunk):
            # chunked prefill and prefix-hit suffixes span multiple rows, so
            # the one-row limit only binds the classic whole-prompt path
            raise ValueError(f"prompt of {req.prompt_len} tokens exceeds "
                             f"prefill_len={self.prefill_len}")
        if self.lazy and req.budget_tokens > self.prefill_len \
                and not (self.share_prefix or self.prefill_chunk):
            # a preempted row re-prefills prompt+generated, which can reach
            # the full budget — reject now rather than overflow a row later
            raise ValueError(
                f"request {rid}: lazy serving needs prefill_len >= the "
                f"prompt+generation budget ({req.budget_tokens}) so a "
                f"preempted sequence can re-prefill")
        if self.max_queue is not None \
                and len(self.scheduler.waiting) >= self.max_queue:
            self._record_outcome(
                rid, Outcome.SHED, [],
                f"admission queue full ({self.max_queue} waiting) — "
                f"reject-newest backpressure")
            return rid
        try:
            self.scheduler.submit(req)
        except AdmissionImpossible as e:
            self._record_outcome(rid, Outcome.SHED, [], str(e))
            return rid
        dl = self.deadline_ms if deadline_ms is None else deadline_ms
        if dl is not None:
            self._deadline_at[rid] = time.perf_counter() + dl / 1e3
        ms = self.max_steps if max_steps is None else max_steps
        if ms is not None:
            self._step_limit[rid] = self._iter + ms
        return rid

    # -- resilience: outcomes, cancellation, deadlines, watchdog, faults ----
    def _record_outcome(self, rid: int, outcome: Outcome, tokens,
                        reason: str = ""):
        """Write a request's terminal record and retire its deadlines.
        Every path that ends a request funnels through here (or through
        :meth:`_terminate_active`, which calls it) — the invariant the
        ``engine-outcome-taxonomy`` lint rule and the chaos tests pin."""
        self._deadline_at.pop(rid, None)
        self._step_limit.pop(rid, None)
        self.results[rid] = RequestResult.make(rid, outcome, tokens, reason)

    def _terminate_active(self, seq: ActiveSeq, outcome: Outcome,
                          reason: str = ""):
        """End a running sequence early: free its slot, pages, and state row
        immediately and record the typed outcome with its partial tokens."""
        sched = self.scheduler
        del sched.active[seq.slot]
        sched.tables.release(seq.slot)
        self._record_outcome(seq.request.rid, outcome, seq.all_generated,
                             reason)

    def _evict_finished(self) -> List[ActiveSeq]:
        """Evict done sequences and record their ``COMPLETED`` outcomes."""
        done = self.scheduler.evict_finished()
        for seq in done:
            self._record_outcome(seq.request.rid, Outcome.COMPLETED,
                                 seq.all_generated)
        return done

    def cancel(self, rid: int, reason: str = "cancelled by client") -> bool:
        """Cancel a request by rid — waiting or mid-flight.  A waiting
        request leaves the queue; an active one releases its slot, pages,
        and state row immediately.  Either way the request terminates with
        a ``CANCELLED`` outcome keeping any tokens generated so far.
        Returns False when no live request has that rid (already finished,
        shed, or never submitted) — cancellation races are expected under
        load and must not raise."""
        req = self.scheduler.remove_waiting(rid)
        if req is not None:
            self._record_outcome(rid, Outcome.CANCELLED,
                                 req.generated_prefix, reason)
            self.cancels += 1
            return True
        for seq in list(self.scheduler.active.values()):
            if seq.request.rid == rid:
                self._terminate_active(seq, Outcome.CANCELLED, reason)
                self.cancels += 1
                return True
        return False

    def _check_deadlines(self):
        """Expire requests past their wall-clock or engine-step budget:
        waiting ones leave the queue, active ones release everything they
        hold — both with a ``TIMEOUT`` outcome naming the budget that fired."""
        sched = self.scheduler
        now = time.perf_counter()
        expired: Dict[int, str] = {}
        for rid, t in self._deadline_at.items():
            if now >= t:
                expired[rid] = "wall-clock deadline expired"
        for rid, limit in self._step_limit.items():
            if self._iter >= limit:
                expired.setdefault(
                    rid, f"engine-step budget exhausted at iteration "
                         f"{self._iter}")
        for rid, why in expired.items():
            req = sched.remove_waiting(rid)
            if req is not None:
                self._record_outcome(rid, Outcome.TIMEOUT,
                                     req.generated_prefix, why)
                continue
            for seq in list(sched.active.values()):
                if seq.request.rid == rid:
                    self._terminate_active(seq, Outcome.TIMEOUT, why)
                    break

    def _release_pocket(self):
        """Return every page the "exhaust" fault pocketed to the allocator —
        at scheduled expiry, before an injected crash, and at loop exit, so
        pool conservation holds at every boundary the tests check."""
        for _, pages in self._fault_pocket:
            self.scheduler.tables.allocator.free(pages)
        self._fault_pocket = []

    def _storm_eligible(self, seq: ActiveSeq) -> bool:
        """A preemption-storm victim must be re-prefillable: with neither
        chunked prefill nor prefix sharing, the resumed prompt+generated
        must still fit one prefill row (lazy admission already guarantees
        that via its submit check; eager does not).  A row that already
        reached its budget is never a victim — resuming a spent request
        would re-prefill it into a one-token overshoot."""
        if seq.done:
            return False
        if self.prefill_chunk or self.share_prefix:
            return True
        return (seq.request.prompt_len + len(seq.generated)) \
            <= self.prefill_len

    def _apply_faults(self):
        """Apply this iteration's :class:`FaultPlan` events at the host
        seams (module docstring of serving/faults.py).  The plan decides,
        this method applies — nothing here touches the jitted steps."""
        plan = self.fault_plan
        sched = self.scheduler
        alloc = sched.tables.allocator
        due = [p for p in self._fault_pocket if p[0] <= self._iter]
        if due:
            self._fault_pocket = [p for p in self._fault_pocket
                                  if p[0] > self._iter]
            for _, pages in due:
                alloc.free(pages)
        if plan.crash_step is not None and self._iter == plan.crash_step:
            self._release_pocket()
            raise InjectedCrash(
                f"injected crash at engine iteration {self._iter}")
        for ev in plan.events_at(self._iter):
            if ev.kind == "exhaust":
                # pocket only the free list: evicting cached pages would
                # destroy live prefix-index content, which real exhaustion
                # (allocation pressure) is allowed to do but a *transient*
                # fault that gives the pages back must not
                n = alloc.num_free
                pages = alloc.alloc(n) if n else None
                if pages:
                    self._fault_pocket.append(
                        (self._iter + plan.pocket_hold, pages))
            elif ev.kind == "storm":
                victims = sorted(
                    (s for s in sched.active.values()
                     if self._storm_eligible(s)),
                    key=lambda s: s.birth, reverse=True)[:1 + ev.arg % 4]
                for v in victims:
                    sched.preempt(v)
            elif ev.kind == "poison":
                pages = alloc.free_page_ids()
                if pages:
                    self._poison_pages(pages)
                if self.has_state:
                    slots = sched.tables.state.free_slot_ids()
                    if slots:
                        self._poison_state(slots)
            elif ev.kind == "nan":
                self._nan_pending.append(ev.arg)
            elif ev.kind == "cancel":
                live = sorted(
                    {r.rid for r in sched.waiting}
                    | {s.request.rid for s in sched.active.values()})
                if live:
                    self.cancel(live[ev.arg % len(live)],
                                reason="fault-plan cancellation")

    def _stuck_diagnostic(self) -> str:
        """One-line pool/queue picture for watchdog and stuck diagnostics."""
        alloc = self.scheduler.tables.allocator
        return (f"free={alloc.num_free} cached={alloc.num_cached} "
                f"allocated={alloc.num_allocated} "
                f"usable={self.pcfg.usable_pages} "
                f"waiting={len(self.scheduler.waiting)} "
                f"active={len(self.scheduler.active)} "
                f"pocketed={sum(len(p) for _, p in self._fault_pocket)}")

    def _watchdog_fire(self):
        """The livelock watchdog tripped: fail one stuck row — the oldest
        active sequence (holding the most resources for the least progress)
        or, with nothing active, the waiting head — with a diagnostic.
        Every firing removes a request, so a wedged engine drains to
        termination instead of hanging."""
        self.watchdog_fires += 1
        self._stall = 0
        sched = self.scheduler
        why = (f"livelock watchdog: no progress for "
               f"{self.watchdog_patience} iterations ({self._stuck_diagnostic()})")
        if sched.active:
            victim = min(sched.active.values(), key=lambda s: s.birth)
            self._terminate_active(victim, Outcome.FAILED, why)
        elif sched.waiting:
            req = sched.waiting.popleft()
            self._record_outcome(req.rid, Outcome.FAILED,
                                 req.generated_prefix, why)

    # -- crash recovery: host-state snapshot / restore ----------------------
    def snapshot(self) -> Dict[str, object]:
        """Capture the full host serving state plus the device caches as
        host arrays — everything needed to resume this engine's work on a
        fresh engine of the same configuration (``restore`` + ``run()``
        continues token-identically; tests/test_chaos.py pins it).  The
        scheduler deep-copy carries block tables, allocator, prefix index,
        and state cache in one consistent piece; wall-clock deadlines are
        stored as *remaining* seconds so a pause between snapshot and
        restore doesn't silently expire them.  Any fault pocket is released
        first so pool conservation holds inside the snapshot."""
        self._release_pocket()
        now = time.perf_counter()
        host = {
            "scheduler": self.scheduler,
            "results": self.results,
            "util_samples": self.util_samples,
            "pool_samples": self.pool_samples,
            "prefill_tokens": self.prefill_tokens,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "next_rid": self._next_rid,
            "iter": self._iter,
            "steps": self._steps,
            "stall": self._stall,
            "watchdog_fires": self.watchdog_fires,
            "cancels": self.cancels,
            "step_limit": self._step_limit,
            "deadline_left": {rid: t - now
                              for rid, t in self._deadline_at.items()},
            "nan_pending": self._nan_pending,
        }
        return {"host": copy.deepcopy(host),
                "caches": tree_map(np.asarray, self.caches)}

    def restore(self, snap: Dict[str, object]):
        """Adopt a :meth:`snapshot`'s state (deep-copied — restoring twice
        from one snapshot is safe).  The engine must be built with the same
        model/pool configuration; a following ``run()`` resumes serving
        exactly where the snapshot left off."""
        host = copy.deepcopy(snap["host"])
        now = time.perf_counter()
        self.scheduler = host["scheduler"]
        self.results = host["results"]
        self.util_samples = host["util_samples"]
        self.pool_samples = host["pool_samples"]
        self.prefill_tokens = host["prefill_tokens"]
        self.drafted_tokens = host["drafted_tokens"]
        self.accepted_tokens = host["accepted_tokens"]
        self._next_rid = host["next_rid"]
        self._iter = host["iter"]
        self._steps = host["steps"]
        self._stall = host["stall"]
        self.watchdog_fires = host["watchdog_fires"]
        self.cancels = host["cancels"]
        self._step_limit = host["step_limit"]
        self._deadline_at = {rid: now + left
                             for rid, left in host["deadline_left"].items()}
        self._nan_pending = host["nan_pending"]
        self._fault_pocket = []
        self.caches = tree_map(jnp.asarray, snap["caches"])

    # -- one packed prefill wave -------------------------------------------
    def _pack_rows(self, seqs: List[ActiveSeq]) -> List[List[ActiveSeq]]:
        """First-fit pack admitted prompts into prefill_len-wide rows."""
        rows: List[List[ActiveSeq]] = [[]]
        used = 0
        for seq in seqs:  # first-fit in admission order
            n = seq.request.prompt_len
            if used + n > self.prefill_len:
                rows.append([])
                used = 0
            rows[-1].append(seq)
            used += n
        return rows

    def _prefill(self, seqs: List[ActiveSeq]):
        """Run classic packed prefill over whole prompts (no cached prefix:
        per-segment positions from zero, in-row segment-masked attention)."""
        tables = self.scheduler.tables
        for row in self._pack_rows(seqs):
            tokens = np.zeros((1, self.prefill_len), np.int32)
            seg = np.full((1, self.prefill_len), -1, np.int32)
            pos = np.zeros((1, self.prefill_len), np.int32)
            slots = np.full((1, self.prefill_len), -1, np.int32)
            off = 0
            last_idx = []
            for i, seq in enumerate(row):
                n = seq.request.prompt_len
                tokens[0, off:off + n] = seq.request.tokens
                seg[0, off:off + n] = i
                pos[0, off:off + n] = np.arange(n)
                slots[0, off:off + n] = seq.slot
                last_idx.append(off + n - 1)
                off += n
            dest = tables.prefill_dest(seg[0], [s.slot for s in row])
            logits, self.caches = self.prefill_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(seg),
                jnp.asarray(pos), jnp.asarray(dest[None]),
                jnp.asarray(slots), self.caches)
            logits = np.asarray(logits[0, :, :self.cfg.vocab_size])
            for seq, li in zip(row, last_idx):
                tables.kv_len[seq.slot] = seq.request.prompt_len
                seq.prefilled = seq.request.prompt_len
                tables.register_prefilled(seq.slot, seq.prefilled)
                seq.generated.append(int(logits[li].argmax()))

    def _prefill_chunks(self, spans: List[Tuple[ActiveSeq, int, int]]):
        """Run chunked/suffix prefill spans — tokens ``[start, end)`` of
        sequences whose earlier tokens already sit in pages (prefix hits or
        earlier chunks).  Spans pack first-fit into prefill_len-wide rows;
        each row scatters its K/V first and attends per-token through the
        owning slot's block-table row, so spans of one prompt may split
        across rows (later rows read earlier rows' pages)."""
        tables = self.scheduler.tables
        width = self.prefill_len
        rows: List[List[Tuple[ActiveSeq, int, int]]] = [[]]
        used = 0
        for sp in spans:
            n = sp[2] - sp[1]
            if used + n > width:
                rows.append([])
                used = 0
            rows[-1].append(sp)
            used += n
        for row in rows:
            tokens = np.zeros((1, width), np.int32)
            pos = np.zeros((1, width), np.int32)
            kvl = np.zeros((1, width), np.int32)   # pad rows finalize to zero
            ttab = np.full((1, width, self.pcfg.max_pages_per_seq),
                           TRASH_PAGE, np.int32)
            dest = np.zeros((1, width), np.int32)  # pad → trash slot 0
            slots = np.full((1, width), -1, np.int32)
            local = np.zeros((1, width), np.int32)
            off = 0
            marks = []
            for seq, a, b in row:
                n = b - a
                tokens[0, off:off + n] = seq.request.tokens[a:b]
                pos[0, off:off + n] = np.arange(a, b)
                kvl[0, off:off + n] = np.arange(a, b) + 1
                ttab[0, off:off + n] = tables.tables[seq.slot]
                dest[0, off:off + n] = tables.span_dest(seq.slot, a, b)
                slots[0, off:off + n] = seq.slot
                local[0, off:off + n] = np.arange(n)
                marks.append((seq, b, off + n - 1))
                off += n
            logits, self.caches = self.chunk_prefill_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(pos),
                jnp.asarray(dest), jnp.asarray(ttab), jnp.asarray(kvl),
                jnp.asarray(slots), jnp.asarray(local), self.caches)
            logits = np.asarray(logits[0, :, :self.cfg.vocab_size])
            for seq, end, li in marks:
                seq.prefilled = end
                tables.kv_len[seq.slot] = end
                tables.register_prefilled(seq.slot, end)
                if end == seq.request.prompt_len:
                    seq.generated.append(int(logits[li].argmax()))

    def _prefill_step(self) -> int:
        """Advance every mid-prompt row, spending at most ``prefill_chunk``
        prompt tokens (unlimited when chunking is off).  Whole prompts with
        no cached prefix take the classic packed path — bit-identical to the
        unshared, unchunked engine — and everything else (prefix-hit
        suffixes, chunk continuations) goes through the per-token path.
        Returns the number of prompt tokens processed."""
        sched = self.scheduler
        pre = [seq for seq in sorted(sched.active.values(),
                                     key=lambda s: s.birth) if seq.prefilling]
        if not pre:
            return 0
        budget = self.prefill_chunk or (1 << 62)
        classic: List[ActiveSeq] = []
        chunks: List[Tuple[ActiveSeq, int, int]] = []
        used = 0
        for seq in pre:
            start = seq.prefilled
            total = seq.request.prompt_len
            while start < total and used < budget:
                end = min(total, start + min(budget - used, self.prefill_len))
                if start == 0 and end == total:
                    classic.append(seq)
                else:
                    chunks.append((seq, start, end))
                used += end - start
                start = end
        if classic:
            self._prefill(classic)
        if chunks:
            self._prefill_chunks(chunks)
        self.prefill_tokens += used
        return used

    def _inject_nan(self, logits: np.ndarray, slots: List[int]
                    ) -> np.ndarray:
        """Apply pending "nan" fault events: corrupt one consumed row's
        logits per event (victim picked by the event arg over the sorted
        consumed slots), exercising the health sentinel below.  Returns the
        (copied, corrupted) logits — device-backed arrays are read-only."""
        if self._nan_pending and slots:
            logits = logits.copy()
            for arg in self._nan_pending:
                logits[sorted(slots)[arg % len(slots)]] = np.nan
            self._nan_pending = []
        return logits

    # -- one decode step over every active slot ----------------------------
    def _decode(self) -> int:
        """One fixed-shape decode step over all max_batch slots.  Mid-prefill
        rows ride along masked — trash table, kv_len 0, token 0 — so their
        half-written pages are neither read nor advanced; their garbage
        logits are ignored like any inactive slot's.  Each consumed row's
        logits pass a health sentinel first: a NaN/inf row is quarantined —
        slot/pages/state freed, ``FAILED`` outcome — instead of emitting
        garbage (its kv_len never advances, so the poisoned write is
        unreachable).  Returns the number of tokens emitted."""
        sched = self.scheduler
        tables = sched.tables
        tok = np.zeros((self.pcfg.max_batch,), np.int32)
        bt, kvl = tables.tables, tables.kv_len
        if any(seq.prefilling for seq in sched.active.values()):
            bt, kvl = bt.copy(), kvl.copy()
            for slot, seq in sched.active.items():
                if seq.prefilling:
                    bt[slot] = TRASH_PAGE
                    kvl[slot] = 0
        for slot, seq in sched.active.items():
            if seq.prefilling:
                continue
            assert tables.append_dest_ok(slot), \
                f"slot {slot}: write position escaped its owned pages"
            tok[slot] = seq.generated[-1]
        logits, self.caches = self.decode_fn(
            self.params, jnp.asarray(tok), self.caches,
            jnp.asarray(bt), jnp.asarray(kvl))
        logits = np.asarray(logits[:, :self.cfg.vocab_size])
        logits = self._inject_nan(logits,
                                  [s for s, q in sched.active.items()
                                   if not q.prefilling])
        finite = np.isfinite(logits).all(axis=-1)
        emitted = 0
        bad: List[ActiveSeq] = []
        for slot, seq in sched.active.items():
            if seq.prefilling:
                continue
            if not finite[slot]:
                bad.append(seq)
                continue
            tables.kv_len[slot] += 1
            seq.generated.append(int(logits[slot].argmax()))
            emitted += 1
        for seq in bad:
            self._terminate_active(
                seq, Outcome.FAILED,
                f"health sentinel: non-finite decode logits (slot "
                f"{seq.slot})")
        return emitted

    def _decode_spec(self) -> int:
        """One fixed-shape [B, k+1] verify step over all max_batch slots.

        Each non-prefilling row carries its current token plus up to ``k``
        prompt-lookup drafts at positions ``kv_len .. kv_len+k`` (per-token
        causal visibility via ``token_kv_len``, exactly like a chunked
        prefill span); mid-prefill and inactive rows pad with the trash
        table, kv_len 0 and dest 0, so they neither read nor write real
        pages.  After the step the longest draft prefix matching the model's
        own greedy argmaxes is accepted (``longest_accept``) and ``kv_len``
        advances by the emitted count — the K/V of rejected drafts stays in
        owned pages at positions ``>= kv_len``, unreadable until the next
        verify overwrites it.  Drafts are budget-capped so the write never
        exceeds the positions ``ensure_growth(k + 1)`` reserved."""
        sched = self.scheduler
        tables = sched.tables
        width = self.speculate_k + 1
        tok = np.zeros((self.pcfg.max_batch, width), np.int32)
        pos = np.zeros((self.pcfg.max_batch, width), np.int32)
        kvl = np.zeros((self.pcfg.max_batch, width), np.int32)
        ttab = np.full((self.pcfg.max_batch, width,
                        self.pcfg.max_pages_per_seq), TRASH_PAGE, np.int32)
        dest = np.zeros((self.pcfg.max_batch, width), np.int32)
        drafts: Dict[int, np.ndarray] = {}
        for slot, seq in sched.active.items():
            if seq.prefilling:
                continue
            history = np.concatenate(
                [seq.request.tokens, np.asarray(seq.generated, np.int32)])
            room = seq.request.max_new_tokens - len(seq.generated)
            draft = self.drafter.propose(history, max_tokens=room - 1)
            m = len(draft) + 1
            L = int(tables.kv_len[slot])
            assert tables.append_dest_ok(slot, m), \
                f"slot {slot}: verify write escaped its owned pages"
            tok[slot, 0] = seq.generated[-1]
            tok[slot, 1:m] = draft
            pos[slot, :m] = L + np.arange(m)
            kvl[slot, :m] = L + 1 + np.arange(m)
            ttab[slot, :m] = tables.tables[slot]
            dest[slot, :m] = tables.span_dest(slot, L, L + m)
            drafts[slot] = draft
            self.drafted_tokens += len(draft)
        logits, self.caches = self.verify_fn(
            self.params, jnp.asarray(tok), jnp.asarray(pos),
            jnp.asarray(dest), jnp.asarray(ttab), jnp.asarray(kvl),
            self.caches)
        logits = np.asarray(logits[:, :, :self.cfg.vocab_size])
        logits = self._inject_nan(logits, list(drafts))
        n_out = 0
        for slot, draft in drafts.items():
            seq = sched.active[slot]
            if not np.isfinite(logits[slot, :len(draft) + 1]).all():
                # health sentinel — same quarantine as plain decode; only
                # the row's live verify positions are checked (masked tail
                # positions legitimately carry garbage)
                self._terminate_active(
                    seq, Outcome.FAILED,
                    f"health sentinel: non-finite verify logits (slot "
                    f"{slot})")
                continue
            greedy = logits[slot, :len(draft) + 1].argmax(axis=-1)
            accepted, emitted = longest_accept(draft, greedy)
            self.accepted_tokens += accepted
            eos = seq.request.eos_id
            if eos is not None and eos in emitted:
                emitted = emitted[:emitted.index(eos) + 1]
            seq.generated.extend(emitted)
            tables.kv_len[slot] += len(emitted)
            n_out += len(emitted)
        return n_out

    def _apply_cow(self):
        """Apply queued copy-on-write page copies to every layer's pools —
        always before the next device step reads the destination pages (the
        sources still hold their pre-step content: freed source pages are
        never rewritten before the next alloc-and-write, which follows)."""
        pairs = self.scheduler.tables.drain_copies()
        if not pairs:
            return
        src = jnp.asarray([s for s, _ in pairs], jnp.int32)
        dst = jnp.asarray([d for _, d in pairs], jnp.int32)
        # the page axis of every pool leaf is ndim-3 ([... Hkv, P, ps, D])
        self.caches = _map_pool_leaves(
            self.caches,
            lambda x: x.at[..., dst, :, :].set(x[..., src, :, :]))

    def _poison_pages(self, pages: List[int]):
        """Test hook: clobber freed pages (plus the trash page their table
        entries now alias) with 1e6 in every layer's pool — reads of a
        reclaimed page then corrupt generations instead of silently reusing
        stale KV.  The window/kv_len gates make poisoned pages inert; the
        reclamation test asserts token-identity under this hook."""
        idx = jnp.asarray(sorted(set(pages) | {TRASH_PAGE}), jnp.int32)
        # the page axis of every pool leaf is ndim-3 ([... Hkv, P, ps, D])
        self.caches = _map_pool_leaves(
            self.caches, lambda x: x.at[..., idx, :, :].set(1e6))

    def _poison_state(self, slots: List[int]):
        """Test hook: clobber released recurrent-state rows (plus the
        trailing trash row) with 1e6 — any read of dead state then corrupts
        generations instead of passing silently.  1e6 rather than NaN
        because legitimately-masked gathers (padding tokens, fresh spans)
        multiply the gathered row by zero.  The slot axis is the row axis:
        position 1 under the stacked superblocks' extra leading layer axis,
        position 0 in tail layers."""
        idx = jnp.asarray(sorted(set(slots)) + [self.pcfg.max_batch],
                          jnp.int32)

        def g(path, x):
            if getattr(path[-1], "key", None) not in ("h", "conv"):
                return x
            if getattr(path[0], "key", None) == "blocks":
                return x.at[:, idx].set(1e6)
            return x.at[idx].set(1e6)

        self.caches = tree_map_with_path(g, self.caches)

    def _drain_state_releases(self):
        """Drain slots whose recurrent-state rows just died (finish or
        preemption) and poison them under the test hook.  Correctness never
        needs host-side zeroing — a re-admitted slot's first prefill span
        starts at position 0, which injects a fresh zero state on device —
        so this only arms the stale-read tripwire.  Called before every
        admission pass, i.e. before any re-admitted slot could prefill."""
        released = self.scheduler.tables.state.drain_released()
        if released and self.poison_reclaimed and self.has_state:
            self._poison_state(released)

    # -- the serving loop ---------------------------------------------------
    def _iteration(self):
        """One engine iteration: evict → faults → deadlines → reclaim →
        grow/COW → admit → prefill → decode/verify → watchdog.  Each call
        either makes progress (tokens, prefill spans, completions) or moves
        the engine strictly closer to a watchdog firing — which removes a
        request — so ``run`` terminates for every reachable state.

        Eviction runs *before* faults and deadlines: a row that reached its
        budget last iteration has completed, and must record ``COMPLETED``
        before a storm can preempt it (which would re-prefill a spent
        request and overshoot its budget by one token) or a deadline can
        mislabel it ``TIMEOUT``."""
        sched = self.scheduler
        done = self._evict_finished()
        if self.fault_plan is not None:
            self._apply_faults()
        if self._deadline_at or self._step_limit:
            self._check_deadlines()
        if sched.idle:
            return
        if self.reclaim and sched.active:
            freed = sched.reclaim(self.window)
            if freed and self.poison_reclaimed:
                self._poison_pages(freed)
        self._drain_state_releases()
        n_pre = sched.preemptions
        if sched.active:
            # running rows claim write pages first — the whole verify
            # span at once under speculation (lookahead = k + 1)
            sched.ensure_growth(self._lookahead)
            self._apply_cow()
        self._drain_state_releases()   # growth-pass preemptions
        admitted = sched.admit()
        if admitted:
            # newly admitted rows may need a copy-on-write before their
            # first prefill span (a shared partial-tail block, or the
            # re-prefilled last token of a fully matched prompt)
            sched.ensure_growth(self._lookahead)
            self._apply_cow()
        progressed = self._prefill_step()
        if progressed:
            done += self._evict_finished()  # max_new == 1 finishes at prefill
        if sched.active:
            # just-prefilled rows may sit exactly on a page boundary;
            # this pass may preempt one of them (its prefill work
            # survives in generated_prefix and resumes later)
            sched.ensure_growth(self._lookahead)
            self._apply_cow()
        emitted = 0
        if any(not seq.prefilling for seq in sched.active.values()):
            u = sched.tables.utilization()
            self.util_samples.append(u["utilization"])
            self.pool_samples.append(u["pool_fraction"])
            emitted = (self._decode_spec() if self.speculate_k
                       else self._decode())
            self._steps += 1
        if emitted or progressed or done:
            # tokens, prefill spans, or completions: real progress — an
            # admitted wave may finish entirely at prefill (max_new == 1),
            # a preemption wave empties the active set to retry next
            # iteration, and a chunked-prefill step may advance prompts
            # without decoding; all reset the watchdog
            self._stall = 0
            return
        if sched.waiting and not sched.active and not admitted \
                and sched.preemptions == n_pre:
            # no admission, no prefill, no preemption, nothing decodable:
            # the waiting head can never be served — fail it with a
            # diagnostic and keep serving the rest (the pre-resilience
            # engine raised here, taking the whole batch down)
            req = sched.waiting.popleft()
            self._record_outcome(
                req.rid, Outcome.FAILED, req.generated_prefix,
                "scheduler stuck: nothing active yet nothing admissible — "
                + self._stuck_diagnostic())
            return
        self._stall += 1
        if self._stall > self.watchdog_patience:
            self._watchdog_fire()

    def run(self, requests: Optional[List[Tuple[np.ndarray, int]]] = None
            ) -> Tuple[Dict[int, np.ndarray], Dict[str, object]]:
        """Serve until the queue drains. requests: (prompt_tokens, max_new)
        pairs to submit first. Returns ({rid: generated tokens} for the
        ``COMPLETED`` requests, stats — with every request's typed outcome
        tallied under ``stats["outcomes"]`` and per-request records in
        ``self.results``)."""
        for tokens, max_new in requests or []:
            self.submit(tokens, max_new)
        sched = self.scheduler
        t0 = time.perf_counter()
        try:
            while not sched.idle:
                self._iteration()
                self._iter += 1
        finally:
            # an injected crash (or any error) must not strand pocketed
            # pages: conservation holds at every exit
            self._release_pocket()
        wall = time.perf_counter() - t0
        steps = self._steps
        out = {rid: res.tokens for rid, res in sorted(self.results.items())
               if res.outcome is Outcome.COMPLETED}
        n_tok = sum(len(g) for g in out.values())
        tables = sched.tables
        stats = {
            "wall_s": wall,
            "decode_steps": float(steps),
            "generated_tokens": float(n_tok),
            "tokens_per_s": n_tok / max(wall, 1e-9),
            "mean_utilization": (float(np.mean(self.util_samples))
                                 if self.util_samples else 0.0),
            "mean_pool_fraction": (float(np.mean(self.pool_samples))
                                   if self.pool_samples else 0.0),
            "preemptions": float(sched.preemptions),
            "pages_grown": float(tables.pages_grown),
            "pages_reclaimed": float(tables.pages_reclaimed),
            "prefill_tokens": float(self.prefill_tokens),
            "prefill_tokens_skipped": float(sched.prefill_skipped),
            "pages_shared": float(tables.pages_shared),
            "pages_allocated": float(tables.allocator.total_allocs),
            "cow_copies": float(tables.cow_copies),
            "state_releases": float(tables.state.releases),
            "drafted_tokens": float(self.drafted_tokens),
            "accepted_tokens": float(self.accepted_tokens),
            "acceptance_rate": (self.accepted_tokens /
                                max(self.drafted_tokens, 1)),
            "watchdog_fires": float(self.watchdog_fires),
            "cancels": float(self.cancels),
            "outcomes": outcome_counts(self.results),
        }
        return out, stats
