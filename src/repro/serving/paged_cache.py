"""Paged KV cache bookkeeping: page allocator + per-sequence block tables.

The device side of the paged cache is a *global page pool* per attention layer
(``k_pages``/``v_pages`` of shape ``[Hkv, num_pages, page_size, D]``, built by
``lm.init_paged_cache``).  This module owns everything host-side:

* :class:`PageAllocator` — a refcounted free list over physical page ids.
  Page 0 is reserved as the **trash page**: freed/unassigned block-table
  entries and padding-token writes all point there, so every table entry the
  kernel's BlockSpec index map reads is a valid page id even for skipped
  blocks.  Every live page carries a refcount (1 for a private page, >1 when
  prefix sharing aliases it into several block tables); double frees and
  trash frees raise instead of silently aliasing two sequences' KV.  A page
  whose refcount hits zero can be *retained* — parked in a cached LRU ring
  because the prefix index still knows its content — and is revived on the
  next prefix hit or evicted when the free list runs dry.
* :class:`PrefixIndex` — a content-addressed index over page-aligned token
  blocks.  Each block's digest chains over its parent's digest plus its
  tokens, so a hit on block ``k`` certifies the *entire* prefix through block
  ``k`` matches — tokens and absolute positions both, which (causal attention
  + global RoPE positions) certifies the cached K/V bytes match too.
* :class:`BlockTables` — per-slot (concurrent-sequence) block tables and
  ``kv_len``, numpy-backed.  Ownership is tracked per *logical block*
  (``slot → {block index → page id}``), which supports both admission
  policies: **eager** reserves a sequence's full page budget up front
  (prompt + generation, so a running batch can never run dry), while
  **lazy** reserves only the prompt pages and grows the decode pages
  (:meth:`grow`) one at a time as ``kv_len`` crosses page boundaries (higher
  pool utilization; the scheduler preempts when growth fails).  With
  ``share_prefix=True`` admission consults the prefix index and points
  matched blocks at the existing physical pages (refcount + 1, no prefill
  compute needed for those tokens), and :meth:`prepare_write` performs
  **copy-on-write**: the first write into a page with refcount > 1 moves the
  writer onto a fresh page (the device copy is queued for the engine to
  apply).  Sliding-window sequences additionally
  :meth:`reclaim_out_of_window` blocks that have slid fully out of the
  attention window — their table entries return to the trash page, which the
  kernels' window gate never reads.  Also computes the flat scatter
  destinations used by packed prefill and reports pool utilization.

Everything here is plain numpy — the jitted steps receive the tables as fresh
(tiny) device arrays each step, which is what lets the scheduler admit/evict
between steps without recompiling anything.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Callable, Dict, FrozenSet, List, Optional, Tuple

import numpy as np

from repro.serving.state_cache import StateCache

TRASH_PAGE = 0  # page 0 absorbs padding writes and backs unassigned entries


def trash_pages_for(num_pages: int, num_shards: int) -> frozenset:
    """Global ids of the per-shard trash pages (page 0 of every shard) —
    the single source for the config's and the allocator's reserved set."""
    per = num_pages // num_shards
    return frozenset(s * per for s in range(num_shards))


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Static geometry of the paged cache (hashable → usable inside jit)."""
    page_size: int = 16          # tokens per KV page
    num_pages: int = 64          # physical pages per layer, incl. trash page(s)
    max_batch: int = 4           # concurrent decode slots
    max_pages_per_seq: int = 16  # block-table width T
    num_shards: int = 1          # page-pool shards (mesh "model" axis size);
                                 # shard s owns pages [s·P, (s+1)·P) and its
                                 # local page 0 (global s·P) is a trash page

    def __post_init__(self):
        if self.num_pages % self.num_shards != 0:
            raise ValueError(
                f"num_pages={self.num_pages} must divide by "
                f"num_shards={self.num_shards}: pool sharding is page-aligned "
                f"(pages never straddle shards)")
        if self.num_pages // self.num_shards < 2:
            raise ValueError("each pool shard needs its trash page plus at "
                             "least one usable page")

    @property
    def max_seq_len(self) -> int:
        """Token capacity of one block-table row (table width × page size)."""
        return self.max_pages_per_seq * self.page_size

    @property
    def trash_pages(self) -> frozenset:
        """Global ids of the per-shard trash pages (page 0 of every shard)."""
        return trash_pages_for(self.num_pages, self.num_shards)

    @property
    def usable_pages(self) -> int:
        """Allocatable pages: the pool minus one trash page per shard."""
        return self.num_pages - self.num_shards

    def pages_for(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` (ceiling division by page_size)."""
        return -(-n_tokens // self.page_size)


class PageAllocator:
    """Refcounted free-list allocator over the non-trash physical page ids.

    Single shard: pages ``1..num_pages-1`` (page 0 is the trash page).
    ``num_shards > 1`` (distributed pool): the first page of every shard —
    global ids ``s · num_pages/num_shards`` — is reserved as that shard's
    trash page (non-local table entries and writes are remapped there), so
    none of them is ever handed out.

    A page is in exactly one of three states:

    * **free** — on the free list, ready for :meth:`alloc`;
    * **allocated** — refcount ≥ 1 (one per block-table entry aliasing it;
      prefix sharing is the only source of refcounts > 1);
    * **cached** — refcount 0 but *retained* because the prefix index still
      maps its content; revivable by :meth:`share` on a prefix hit, or
      evicted LRU-first by :meth:`alloc` when the free list runs dry
      (``on_evict`` fires so the index can forget it).

    Conservation (the fuzz test's invariant):
    ``num_free + num_cached + num_allocated == usable pages``.
    """

    def __init__(self, num_pages: int, num_shards: int = 1):
        assert num_pages >= 2, "need at least the trash page + one real page"
        assert num_pages % num_shards == 0, "pool sharding is page-aligned"
        self._trash = trash_pages_for(num_pages, num_shards)
        self._free: List[int] = [p for p in range(num_pages - 1, 0, -1)
                                 if p not in self._trash]  # pop() → lowest id
        self._refs: Dict[int, int] = {}              # page → refcount (≥ 1)
        self._cached: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()                # ref-0 retained, LRU first
        self.on_evict: Optional[Callable[[int], None]] = None
        self.num_pages = num_pages
        self.total_allocs = 0   # pages ever handed out fresh (stats)
        self.revivals = 0       # cached pages brought back by a prefix hit

    @property
    def num_free(self) -> int:
        """Pages on the free list (immediately allocatable, content dead)."""
        return len(self._free)

    @property
    def num_cached(self) -> int:
        """Retained ref-0 pages (allocatable after evicting their content)."""
        return len(self._cached)

    @property
    def num_allocated(self) -> int:
        """Distinct physical pages with refcount ≥ 1."""
        return len(self._refs)

    @property
    def refs_total(self) -> int:
        """Sum of all refcounts — equals the block-table ownership entries."""
        return sum(self._refs.values())

    def refcount(self, page: int) -> int:
        """Current refcount of a page (0 when free or cached)."""
        return self._refs.get(page, 0)

    def free_page_ids(self) -> List[int]:
        """Snapshot of the free list (content-dead, immediately allocatable).
        Cached pages are *not* included — their device content is live in
        the prefix index and must survive until eviction.  The chaos
        harness's ``poison`` fault clobbers exactly these pages to prove
        nothing ever reads freed storage."""
        return list(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Hand out ``n`` fresh pages at refcount 1, or return None (and
        leave the pool untouched) if free + cached can't cover it.  The free
        list is drained first; then cached pages are evicted oldest-first,
        firing ``on_evict`` so the prefix index forgets their content."""
        if n > len(self._free) + len(self._cached):
            return None
        got: List[int] = []
        while len(got) < n and self._free:
            got.append(self._free.pop())
        while len(got) < n:
            page, _ = self._cached.popitem(last=False)   # LRU eviction
            if self.on_evict is not None:
                self.on_evict(page)
            got.append(page)
        for p in got:
            self._refs[p] = 1
        self.total_allocs += n
        return got

    def share(self, page: int):
        """Add one reference to an allocated or cached page (a prefix-cache
        hit aliasing it into another block table).  Reviving a cached page
        moves it back to refcount 1 without touching its device content."""
        if page in self._refs:
            self._refs[page] += 1
        elif page in self._cached:
            del self._cached[page]
            self._refs[page] = 1
            self.revivals += 1
        else:
            raise ValueError(f"page {page} is not allocated or cached — "
                             f"cannot share a free page")

    def free(self, pages: List[int],
             retain: FrozenSet[int] = frozenset()) -> List[int]:
        """Drop one reference per page; pages reaching refcount 0 return to
        the free list — unless listed in ``retain`` (the prefix index still
        maps their content), in which case they park in the cached ring.
        Raises on a trash page or a page with no outstanding reference (the
        double-free that used to silently alias two sequences' KV).
        Returns the pages that actually went back to the free list, so the
        engine's ``poison_reclaimed`` hook clobbers only truly dead pages."""
        released: List[int] = []
        for p in pages:
            if p in self._trash:
                raise ValueError(f"page {p} is a trash page — never allocated")
            if p not in self._refs:
                raise ValueError(f"page {p} has no outstanding reference — "
                                 f"double free")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                if p in retain:
                    self._cached[p] = None   # most-recently-used end
                else:
                    released.append(p)
        self._free.extend(released)
        return released


class PrefixIndex:
    """Content-addressed map from page-aligned token blocks to physical pages.

    Block ``k`` of a prompt is hashed as ``blake2b(digest(k-1) ‖ tokens[k·ps
    : min((k+1)·ps, n)])`` — the chaining makes a digest stand for the whole
    prefix through its block, so equal digests imply equal tokens *at equal
    absolute positions*, which (causal attention + positions-from-zero RoPE)
    implies bit-equal cached K/V.  Full blocks and the final partial block
    both index; a partial block's digest covers its exact token count, so
    only an identical-length identical tail matches it.

    Entries are registered only *after* the block's KV has been written
    (post-prefill) and forgotten when the allocator evicts the backing page.
    A registered page's indexed tokens never change: appends land at offsets
    past them, and any write to a page with refcount > 1 goes through
    copy-on-write first.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._pages: Dict[bytes, int] = {}    # digest → physical page
        self._digests: Dict[int, bytes] = {}  # physical page → digest
        self.hits = 0     # admission-time block matches (stats)
        self.misses = 0   # admission-time block lookups that missed

    @staticmethod
    def _digest(parent: bytes, tokens: np.ndarray) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(parent)
        h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
        return h.digest()

    def block_digests(self, tokens: np.ndarray) -> List[bytes]:
        """Chained digests for every block a prompt covers (the last one may
        be partial)."""
        tokens = np.asarray(tokens, np.int32)
        n = int(tokens.shape[0])
        ps = self.page_size
        out: List[bytes] = []
        parent = b""
        for blk in range(-(-n // ps)):
            parent = self._digest(parent, tokens[blk * ps:min((blk + 1) * ps,
                                                              n)])
            out.append(parent)
        return out

    def lookup(self, digest: bytes) -> Optional[int]:
        """The physical page registered for a block digest, if any."""
        page = self._pages.get(digest)
        if page is None:
            self.misses += 1
        else:
            self.hits += 1
        return page

    def register(self, digest: bytes, page: int) -> bool:
        """Publish a freshly prefilled block.  First writer wins: a digest
        already mapped, or a page already registered under another digest,
        is left alone (returns False)."""
        if digest in self._pages or page in self._digests:
            return False
        self._pages[digest] = page
        self._digests[page] = digest
        return True

    def registered(self, page: int) -> bool:
        """Is this physical page currently indexed?"""
        return page in self._digests

    def forget(self, page: int):
        """Drop a page's entry (allocator eviction: its content is about to
        be overwritten by a new owner)."""
        digest = self._digests.pop(page, None)
        if digest is not None and self._pages.get(digest) == page:
            del self._pages[digest]

    def __len__(self) -> int:
        return len(self._pages)


class BlockTables:
    """Per-slot block tables + lengths over one shared :class:`PageAllocator`.

    Ownership is per logical block (``slot → {block → page}``), so a row's
    owned blocks need not be a prefix of its table: lazy growth appends the
    next write block on demand, and sliding-window reclamation removes fully
    out-of-window blocks from the low end (their entries revert to the trash
    page — inert by the kernels' ``kv_len``/window gates).

    With ``share_prefix=True`` a :class:`PrefixIndex` rides along: admission
    points matched prompt blocks at existing pages (sharing the refcount),
    releases park still-indexed pages in the allocator's cached ring instead
    of freeing them, and :meth:`prepare_write` copy-on-writes the first
    divergent write to a shared page.  The device-side page copies a COW
    produces are queued in ``drain_copies`` order for the engine to apply
    before the next prefill/decode step.

    A :class:`~repro.serving.state_cache.StateCache` rides along as
    ``self.state``: hybrid SSM/recurrent archs keep O(1) per-slot state
    rows next to the page pool, and the same admit/release calls that bind
    a slot's pages bind its state row — preemption and eviction free both
    atomically (attention-only archs just never read the rows).
    """

    def __init__(self, cfg: PagedCacheConfig, share_prefix: bool = False):
        self.cfg = cfg
        self.allocator = PageAllocator(cfg.num_pages, cfg.num_shards)
        self.prefix: Optional[PrefixIndex] = (
            PrefixIndex(cfg.page_size) if share_prefix else None)
        if self.prefix is not None:
            self.allocator.on_evict = self.prefix.forget
        self.tables = np.full((cfg.max_batch, cfg.max_pages_per_seq),
                              TRASH_PAGE, np.int32)
        self.kv_len = np.zeros((cfg.max_batch,), np.int32)
        self.state = StateCache(cfg.max_batch)  # per-slot recurrent state
        self._owned: Dict[int, Dict[int, int]] = {}  # slot → {block → page}
        self._digests: Dict[int, Tuple[List[bytes], int]] = {}
        # slot → (block digest chain of its prompt, prompt length): consumed
        # by register_prefilled as the prompt's blocks finish writing
        self._pending_copies: List[Tuple[int, int, int]] = []
        # COW queue: (slot, src page, dst page) in issue order; the engine
        # applies them as device copies before the next step reads dst
        self.hist: Dict[int, int] = {}  # slot → prefix tokens matched at admit
        self.pages_grown = 0        # lazily-allocated decode pages (stats)
        self.pages_reclaimed = 0    # out-of-window pages freed early (stats)
        self.pages_shared = 0       # block-table entries served by a hit
        self.cow_copies = 0         # copy-on-write page copies queued

    def free_slots(self) -> List[int]:
        """Decode slots not currently backing a sequence."""
        return [s for s in range(self.cfg.max_batch) if s not in self._owned]

    def _match_prefix(self, tokens: Optional[np.ndarray]
                      ) -> Tuple[int, Dict[int, int], Optional[List[bytes]]]:
        """Walk the prompt's digest chain against the index: returns (matched
        token count, {block → existing page}, the full digest chain).  The
        match is capped at ``prompt_len - 1`` so prefill always processes at
        least the prompt's last token — its logits seed generation."""
        if self.prefix is None or tokens is None:
            return 0, {}, None
        tokens = np.asarray(tokens, np.int32)
        n_prompt = int(tokens.shape[0])
        digests = self.prefix.block_digests(tokens)
        ps = self.cfg.page_size
        hist = 0
        matched: Dict[int, int] = {}
        for blk, digest in enumerate(digests):
            page = self.prefix.lookup(digest)
            if page is None:
                break
            end = min((blk + 1) * ps, n_prompt)
            if end >= n_prompt:
                end = n_prompt - 1          # keep the last token for prefill
                if end <= blk * ps:
                    break                   # block would contribute nothing
            matched[blk] = page
            hist = end
            if end < (blk + 1) * ps:
                break                       # partial tail ends the chain
        return hist, matched, digests

    def admit(self, slot: int, n_tokens: int, first_block: int = 0,
              tokens: Optional[np.ndarray] = None) -> bool:
        """Reserve the pages covering ``n_tokens`` at logical blocks
        ``first_block .. pages_for(n_tokens)-1``.

        Eager admission passes the full lifetime budget (prompt + gen);
        lazy admission passes only the prompt (decode pages come from
        :meth:`grow`).  Sliding-window admission skips blocks already dead
        on arrival via ``first_block`` — a resumed long-tail prompt then
        reserves only its O(window) live tail, not the whole prefix; prefill
        writes into skipped blocks land in the trash page (their table
        entries stay 0) and the kernels' window gate never reads them.

        With prefix sharing, pass the *prompt* ``tokens``: blocks whose
        chained digest is already indexed alias the existing physical pages
        (refcount + 1; dead-on-arrival blocks below ``first_block`` are
        matched for compute-skipping but get no page), and ``hist[slot]``
        records how many prompt tokens are already resident — the engine
        prefills only the remainder.  All-or-nothing: False (no side effect)
        when the pool can't cover the unmatched blocks.
        """
        assert slot not in self._owned
        if n_tokens > self.cfg.max_seq_len:
            raise ValueError(
                f"sequence of {n_tokens} tokens exceeds the block-table "
                f"capacity {self.cfg.max_seq_len} (raise max_pages_per_seq)")
        n_blocks = self.cfg.pages_for(n_tokens)
        assert 0 <= first_block < n_blocks
        hist, matched, digests = self._match_prefix(tokens)
        shared = {blk: page for blk, page in matched.items()
                  if blk >= first_block}
        # take the shared references first: alloc() below may otherwise evict
        # the very cached pages the match found
        for page in shared.values():
            self.allocator.share(page)
        need = [blk for blk in range(first_block, n_blocks)
                if blk not in shared]
        pages = self.allocator.alloc(len(need))
        if pages is None:
            if shared:   # roll back, parking revived pages back in the cache
                self.allocator.free(list(shared.values()),
                                    retain=frozenset(shared.values()))
            return False
        owned = dict(shared)
        owned.update(zip(need, pages))
        self._owned[slot] = owned
        self.state.admit(slot)   # bind the slot's recurrent-state row too
        self.tables[slot] = TRASH_PAGE
        for blk, page in owned.items():
            self.tables[slot, blk] = page
        self.kv_len[slot] = hist   # matched tokens are already resident
        self.hist[slot] = hist
        if digests is not None:
            self._digests[slot] = (digests, int(np.asarray(tokens).shape[0]))
        self.pages_shared += len(shared)
        return True

    def _ensure_block(self, slot: int, blk: int) -> bool:
        """Ensure one specific logical block is owned, allocating a page if
        it isn't.  Idempotent; returns False (no side effect) when a page is
        needed but the pool is dry — the scheduler's cue to preempt."""
        owned = self._owned[slot]
        if blk in owned:
            return True
        if blk >= self.cfg.max_pages_per_seq:
            raise ValueError(
                f"slot {slot}: write block {blk} escapes the block-table "
                f"capacity {self.cfg.max_seq_len}")
        pages = self.allocator.alloc(1)
        if pages is None:
            return False
        owned[blk] = pages[0]
        self.tables[slot, blk] = pages[0]
        self.pages_grown += 1
        return True

    def grow(self, slot: int) -> bool:
        """Ensure the next token's write block (``kv_len // page_size``) is
        owned, allocating one page if it isn't.  Idempotent; returns False
        (no side effect) when a page is needed but the pool is dry — the
        scheduler's cue to preempt."""
        return self._ensure_block(slot,
                                  int(self.kv_len[slot]) // self.cfg.page_size)

    def prepare_write(self, slot: int, n: int = 1) -> bool:
        """Make the blocks covering the next ``n`` token writes (positions
        ``kv_len .. kv_len + n - 1``) owned and exclusively writable,
        copy-on-writing shared pages as needed.

        ``n = 1`` is the plain decode step; speculative decode passes
        ``k + 1`` so one verify call can scatter a row's whole draft, which
        may cross one or more page boundaries in a single step — every
        boundary crossed grows one page.  A missing block *below* the row's
        highest owned block is a window-skipped dead zone — mid-prefill
        writes there go to the trash page by design, so nothing is
        allocated; missing blocks above are genuine appends.  When a write
        block's page has refcount > 1 — a prefix-shared page this row is
        about to diverge from — the row moves to a fresh page: the device
        copy is queued in ``_pending_copies``, the table entry is rewritten,
        and the shared page loses one reference.  Returns False (pool dry)
        as the scheduler's cue to preempt; pages already granted for earlier
        blocks of the range stay owned (they are the row's future write
        blocks — release/preemption reclaims them like any owned page).
        """
        assert n >= 1
        owned = self._owned[slot]
        ps = self.cfg.page_size
        first = int(self.kv_len[slot]) // ps
        last = (int(self.kv_len[slot]) + n - 1) // ps
        for blk in range(first, last + 1):
            if blk not in owned:
                if owned and blk < max(owned):
                    continue   # window-skipped block: writes go to trash
                if not self._ensure_block(slot, blk):
                    return False
            page = owned.get(blk)
            if page is not None and self.allocator.refcount(page) > 1:
                fresh = self.allocator.alloc(1)
                if fresh is None:
                    return False
                retain = (frozenset([page]) if self.prefix is not None
                          and self.prefix.registered(page) else frozenset())
                self.allocator.free([page], retain=retain)
                owned[blk] = fresh[0]
                self.tables[slot, blk] = fresh[0]
                self._pending_copies.append((slot, page, fresh[0]))
                self.cow_copies += 1
        return True

    def drain_copies(self) -> List[Tuple[int, int]]:
        """Take the queued COW page copies as (src, dst) pairs in issue
        order; the engine applies them to every layer's pools before the
        next step reads the destination pages."""
        pairs = [(src, dst) for _, src, dst in self._pending_copies]
        self._pending_copies = []
        return pairs

    def register_prefilled(self, slot: int, upto: int):
        """Publish the prompt blocks whose content is fully written now that
        ``upto`` tokens are prefilled — full blocks as they complete, the
        partial tail once the whole prompt is in.  No-op without sharing or
        for window-skipped (trash-backed) blocks."""
        entry = self._digests.get(slot)
        if self.prefix is None or entry is None:
            return
        digests, n_tokens = entry
        ps = self.cfg.page_size
        owned = self._owned[slot]
        for blk, digest in enumerate(digests):
            end = min((blk + 1) * ps, n_tokens)
            if end > upto:
                break
            page = owned.get(blk)
            if page is not None:
                self.prefix.register(digest, page)

    def _retained(self, pages: List[int]) -> FrozenSet[int]:
        """The subset of pages the prefix index still maps — releases park
        these in the allocator's cached ring instead of the free list."""
        if self.prefix is None:
            return frozenset()
        return frozenset(p for p in pages if self.prefix.registered(p))

    def reclaim_out_of_window(self, slot: int, window: int) -> List[int]:
        """Free this row's blocks that have slid fully out of a sliding
        attention window; returns the page ids that actually went back to
        the free list (shared or index-retained pages survive with their
        content — the engine's poison hook must not clobber those).

        At the next decode step the query sits at position ``kv_len`` and the
        kernels admit keys at positions ``kp > kv_len - window`` (the same
        gate in the Pallas grid skip and the XLA fallback mask).  A block is
        dead once its *last* position ``(blk+1)·page_size - 1`` falls at or
        below ``kv_len - window`` — and stays dead, since ``kv_len`` only
        grows.  Its table entry reverts to the trash page, which the window
        gate skips without reading.
        """
        owned = self._owned.get(slot)
        if not owned:
            return []
        ps = self.cfg.page_size
        horizon = int(self.kv_len[slot]) - window  # last masked-out position
        freed = []
        for blk in sorted(owned):
            if (blk + 1) * ps - 1 > horizon:
                break                      # blocks are dead low-end-first
            freed.append(owned.pop(blk))
            self.tables[slot, blk] = TRASH_PAGE
        if not freed:
            return []
        self.pages_reclaimed += len(freed)
        return self.allocator.free(freed, retain=self._retained(freed))

    def release(self, slot: int) -> List[int]:
        """Return every page a slot owns (finish, EOS, or preemption);
        still-indexed pages park in the allocator's cached ring so future
        identical prefixes can revive them.  Queued COW copies for the slot
        are dropped (their destination pages just went away).  Returns the
        page ids that actually went back to the free list."""
        pages = list(self._owned.pop(slot).values())
        self.state.release(slot)   # the slot's recurrent-state row dies too
        self.tables[slot] = TRASH_PAGE
        self.kv_len[slot] = 0
        self._digests.pop(slot, None)
        self.hist.pop(slot, None)
        self._pending_copies = [c for c in self._pending_copies
                                if c[0] != slot]
        return self.allocator.free(pages, retain=self._retained(pages))

    def prefill_dest(self, segment_ids_row: np.ndarray,
                     slots: List[int]) -> np.ndarray:
        """Flat page-pool token slots for one packed prefill row.

        segment_ids_row [S]: ids 0..n-1 over contiguous prompt spans, -1 pad;
        slots[i]: the cache slot backing segment i.  Returns dest [S] int32 —
        token t of segment i lands in ``table[t // ps] * ps + t % ps`` of slot
        ``slots[i]``'s table; padding lands in the trash page's slot 0.
        """
        ps = self.cfg.page_size
        dest = np.zeros(segment_ids_row.shape, np.int32)  # pad → trash slot 0
        for i, slot in enumerate(slots):
            (pos,) = np.nonzero(segment_ids_row == i)
            local = np.arange(len(pos))
            dest[pos] = self.tables[slot, local // ps] * ps + local % ps
        return dest

    def span_dest(self, slot: int, start: int, end: int) -> np.ndarray:
        """Flat page-pool token slots for tokens ``[start, end)`` of one
        sequence — the chunked-prefill scatter destinations (positions are
        global, unlike :meth:`prefill_dest`'s per-segment layout).  Tokens in
        window-skipped blocks map through the trash table entry."""
        ps = self.cfg.page_size
        pos = np.arange(start, end)
        return (self.tables[slot, pos // ps] * ps + pos % ps).astype(np.int32)

    def append_dest_ok(self, slot: int, n: int = 1) -> bool:
        """Do the next ``n`` tokens' write positions all fall inside owned
        pages?  (The decode/verify steps assert this before scattering.)"""
        ps = self.cfg.page_size
        first = int(self.kv_len[slot]) // ps
        last = (int(self.kv_len[slot]) + n - 1) // ps
        owned = self._owned.get(slot, {})
        return all(blk in owned for blk in range(first, last + 1))

    def utilization(self) -> Dict[str, float]:
        """Live tokens vs. reserved page capacity — the admission-policy
        metric: eager full-budget reservation holds pages long before tokens
        exist, lazy growth tracks the live set (and reclamation drops tokens
        that slid out of the window along with their pages).  ``utilization``
        counts logical blocks (a shared page counts once per alias);
        ``pool_fraction`` counts distinct physical pages, so prefix sharing
        drives it *down* while utilization holds."""
        ps = self.cfg.page_size
        allocated = sum(len(p) for p in self._owned.values())
        cap = allocated * ps
        used = 0                     # tokens resident in *owned* pages
        for slot, owned in self._owned.items():
            n = int(self.kv_len[slot])
            used += sum(max(0, min(ps, n - blk * ps)) for blk in owned)
        physical = self.allocator.num_allocated
        return {
            "used_tokens": float(used),
            "allocated_tokens": float(cap),
            "allocated_pages": float(allocated),
            "physical_pages": float(physical),
            "pool_pages": float(self.cfg.usable_pages),
            "utilization": used / cap if cap else 0.0,
            "pool_fraction": physical / self.cfg.usable_pages,
        }
