"""Paged KV cache bookkeeping: page allocator + per-sequence block tables.

The device side of the paged cache is a *global page pool* per attention layer
(``k_pages``/``v_pages`` of shape ``[Hkv, num_pages, page_size, D]``, built by
``lm.init_paged_cache``).  This module owns everything host-side:

* :class:`PageAllocator` — a free list over physical page ids.  Page 0 is
  reserved as the **trash page**: freed/unassigned block-table entries and
  padding-token writes all point there, so every table entry the kernel's
  BlockSpec index map reads is a valid page id even for skipped blocks.
* :class:`BlockTables` — per-slot (concurrent-sequence) block tables and
  ``kv_len``, numpy-backed; admission reserves a sequence's full page budget
  up front (prompt + generation) and release returns it, so a running batch
  can never OOM mid-flight.  Also computes the flat scatter destinations used
  by packed prefill and reports pool utilization.

Everything here is plain numpy — the jitted steps receive the tables as fresh
(tiny) device arrays each step, which is what lets the scheduler admit/evict
between steps without recompiling anything.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

TRASH_PAGE = 0  # page 0 absorbs padding writes and backs unassigned entries


def trash_pages_for(num_pages: int, num_shards: int) -> frozenset:
    """Global ids of the per-shard trash pages (page 0 of every shard) —
    the single source for the config's and the allocator's reserved set."""
    per = num_pages // num_shards
    return frozenset(s * per for s in range(num_shards))


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Static geometry of the paged cache (hashable → usable inside jit)."""
    page_size: int = 16          # tokens per KV page
    num_pages: int = 64          # physical pages per layer, incl. trash page(s)
    max_batch: int = 4           # concurrent decode slots
    max_pages_per_seq: int = 16  # block-table width T
    num_shards: int = 1          # page-pool shards (mesh "model" axis size);
                                 # shard s owns pages [s·P, (s+1)·P) and its
                                 # local page 0 (global s·P) is a trash page

    def __post_init__(self):
        if self.num_pages % self.num_shards != 0:
            raise ValueError(
                f"num_pages={self.num_pages} must divide by "
                f"num_shards={self.num_shards}: pool sharding is page-aligned "
                f"(pages never straddle shards)")
        if self.num_pages // self.num_shards < 2:
            raise ValueError("each pool shard needs its trash page plus at "
                             "least one usable page")

    @property
    def max_seq_len(self) -> int:
        return self.max_pages_per_seq * self.page_size

    @property
    def trash_pages(self) -> frozenset:
        """Global ids of the per-shard trash pages (page 0 of every shard)."""
        return trash_pages_for(self.num_pages, self.num_shards)

    @property
    def usable_pages(self) -> int:
        return self.num_pages - self.num_shards

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)


class PageAllocator:
    """Free-list allocator over the non-trash physical page ids.

    Single shard: pages ``1..num_pages-1`` (page 0 is the trash page).
    ``num_shards > 1`` (distributed pool): the first page of every shard —
    global ids ``s · num_pages/num_shards`` — is reserved as that shard's
    trash page (non-local table entries and writes are remapped there), so
    none of them is ever handed out.
    """

    def __init__(self, num_pages: int, num_shards: int = 1):
        assert num_pages >= 2, "need at least the trash page + one real page"
        assert num_pages % num_shards == 0, "pool sharding is page-aligned"
        self._trash = trash_pages_for(num_pages, num_shards)
        self._free: List[int] = [p for p in range(num_pages - 1, 0, -1)
                                 if p not in self._trash]  # pop() → lowest id
        self.num_pages = num_pages

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages, or None (and no side effect) if the pool can't cover it."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]):
        for p in pages:
            assert p not in self._trash, "trash pages are never allocated"
        self._free.extend(pages)


class BlockTables:
    """Per-slot block tables + lengths over one shared :class:`PageAllocator`."""

    def __init__(self, cfg: PagedCacheConfig):
        self.cfg = cfg
        self.allocator = PageAllocator(cfg.num_pages, cfg.num_shards)
        self.tables = np.full((cfg.max_batch, cfg.max_pages_per_seq),
                              TRASH_PAGE, np.int32)
        self.kv_len = np.zeros((cfg.max_batch,), np.int32)
        self._owned: Dict[int, List[int]] = {}   # slot → allocated page ids

    def free_slots(self) -> List[int]:
        return [s for s in range(self.cfg.max_batch) if s not in self._owned]

    def admit(self, slot: int, n_tokens: int) -> bool:
        """Reserve pages for a sequence's full lifetime (prompt + gen)."""
        assert slot not in self._owned
        if n_tokens > self.cfg.max_seq_len:
            raise ValueError(
                f"sequence of {n_tokens} tokens exceeds the block-table "
                f"capacity {self.cfg.max_seq_len} (raise max_pages_per_seq)")
        pages = self.allocator.alloc(self.cfg.pages_for(n_tokens))
        if pages is None:
            return False
        self._owned[slot] = pages
        self.tables[slot] = TRASH_PAGE
        self.tables[slot, :len(pages)] = pages
        self.kv_len[slot] = 0
        return True

    def release(self, slot: int):
        self.allocator.free(self._owned.pop(slot))
        self.tables[slot] = TRASH_PAGE
        self.kv_len[slot] = 0

    def prefill_dest(self, segment_ids_row: np.ndarray,
                     slots: List[int]) -> np.ndarray:
        """Flat page-pool token slots for one packed prefill row.

        segment_ids_row [S]: ids 0..n-1 over contiguous prompt spans, -1 pad;
        slots[i]: the cache slot backing segment i.  Returns dest [S] int32 —
        token t of segment i lands in ``table[t // ps] * ps + t % ps`` of slot
        ``slots[i]``'s table; padding lands in the trash page's slot 0.
        """
        ps = self.cfg.page_size
        dest = np.zeros(segment_ids_row.shape, np.int32)  # pad → trash slot 0
        for i, slot in enumerate(slots):
            (pos,) = np.nonzero(segment_ids_row == i)
            local = np.arange(len(pos))
            dest[pos] = self.tables[slot, local // ps] * ps + local % ps
        return dest

    def append_dest_ok(self, slot: int) -> bool:
        """Does the next token's write position fall inside owned pages?"""
        page = int(self.kv_len[slot]) // self.cfg.page_size
        return page < len(self._owned.get(slot, ()))

    def utilization(self) -> Dict[str, float]:
        """Live tokens vs. reserved page capacity (the paged-vs-contiguous
        memory argument: contiguous reserves max_batch × max_seq_len always)."""
        allocated = sum(len(p) for p in self._owned.values())
        cap = allocated * self.cfg.page_size
        used = int(self.kv_len.sum())
        return {
            "used_tokens": float(used),
            "allocated_tokens": float(cap),
            "allocated_pages": float(allocated),
            "pool_pages": float(self.cfg.usable_pages),
            "utilization": used / cap if cap else 0.0,
            "pool_fraction": allocated / self.cfg.usable_pages,
        }
