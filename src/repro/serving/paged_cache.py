"""Paged KV cache bookkeeping: page allocator + per-sequence block tables.

The device side of the paged cache is a *global page pool* per attention layer
(``k_pages``/``v_pages`` of shape ``[Hkv, num_pages, page_size, D]``, built by
``lm.init_paged_cache``).  This module owns everything host-side:

* :class:`PageAllocator` — a free list over physical page ids.  Page 0 is
  reserved as the **trash page**: freed/unassigned block-table entries and
  padding-token writes all point there, so every table entry the kernel's
  BlockSpec index map reads is a valid page id even for skipped blocks.
* :class:`BlockTables` — per-slot (concurrent-sequence) block tables and
  ``kv_len``, numpy-backed.  Ownership is tracked per *logical block*
  (``slot → {block index → page id}``), which supports both admission
  policies: **eager** reserves a sequence's full page budget up front
  (prompt + generation, so a running batch can never run dry), while
  **lazy** reserves only the prompt pages and grows the decode pages
  (:meth:`grow`) one at a time as ``kv_len`` crosses page boundaries (higher pool
  utilization; the scheduler preempts when growth fails).  Sliding-window
  sequences additionally :meth:`reclaim_out_of_window` blocks that have
  slid fully out of the attention window — their table entries return to
  the trash page, which the kernels' window gate never reads.  Also
  computes the flat scatter destinations used by packed prefill and
  reports pool utilization.

Everything here is plain numpy — the jitted steps receive the tables as fresh
(tiny) device arrays each step, which is what lets the scheduler admit/evict
between steps without recompiling anything.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

TRASH_PAGE = 0  # page 0 absorbs padding writes and backs unassigned entries


def trash_pages_for(num_pages: int, num_shards: int) -> frozenset:
    """Global ids of the per-shard trash pages (page 0 of every shard) —
    the single source for the config's and the allocator's reserved set."""
    per = num_pages // num_shards
    return frozenset(s * per for s in range(num_shards))


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Static geometry of the paged cache (hashable → usable inside jit)."""
    page_size: int = 16          # tokens per KV page
    num_pages: int = 64          # physical pages per layer, incl. trash page(s)
    max_batch: int = 4           # concurrent decode slots
    max_pages_per_seq: int = 16  # block-table width T
    num_shards: int = 1          # page-pool shards (mesh "model" axis size);
                                 # shard s owns pages [s·P, (s+1)·P) and its
                                 # local page 0 (global s·P) is a trash page

    def __post_init__(self):
        if self.num_pages % self.num_shards != 0:
            raise ValueError(
                f"num_pages={self.num_pages} must divide by "
                f"num_shards={self.num_shards}: pool sharding is page-aligned "
                f"(pages never straddle shards)")
        if self.num_pages // self.num_shards < 2:
            raise ValueError("each pool shard needs its trash page plus at "
                             "least one usable page")

    @property
    def max_seq_len(self) -> int:
        """Token capacity of one block-table row (table width × page size)."""
        return self.max_pages_per_seq * self.page_size

    @property
    def trash_pages(self) -> frozenset:
        """Global ids of the per-shard trash pages (page 0 of every shard)."""
        return trash_pages_for(self.num_pages, self.num_shards)

    @property
    def usable_pages(self) -> int:
        """Allocatable pages: the pool minus one trash page per shard."""
        return self.num_pages - self.num_shards

    def pages_for(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` (ceiling division by page_size)."""
        return -(-n_tokens // self.page_size)


class PageAllocator:
    """Free-list allocator over the non-trash physical page ids.

    Single shard: pages ``1..num_pages-1`` (page 0 is the trash page).
    ``num_shards > 1`` (distributed pool): the first page of every shard —
    global ids ``s · num_pages/num_shards`` — is reserved as that shard's
    trash page (non-local table entries and writes are remapped there), so
    none of them is ever handed out.
    """

    def __init__(self, num_pages: int, num_shards: int = 1):
        assert num_pages >= 2, "need at least the trash page + one real page"
        assert num_pages % num_shards == 0, "pool sharding is page-aligned"
        self._trash = trash_pages_for(num_pages, num_shards)
        self._free: List[int] = [p for p in range(num_pages - 1, 0, -1)
                                 if p not in self._trash]  # pop() → lowest id
        self.num_pages = num_pages

    @property
    def num_free(self) -> int:
        """Pages currently available to alloc()."""
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages, or None (and no side effect) if the pool can't cover it."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]):
        """Return pages to the pool (release, preemption or reclamation)."""
        for p in pages:
            assert p not in self._trash, "trash pages are never allocated"
        self._free.extend(pages)


class BlockTables:
    """Per-slot block tables + lengths over one shared :class:`PageAllocator`.

    Ownership is per logical block (``slot → {block → page}``), so a row's
    owned blocks need not be a prefix of its table: lazy growth appends the
    next write block on demand, and sliding-window reclamation removes fully
    out-of-window blocks from the low end (their entries revert to the trash
    page — inert by the kernels' ``kv_len``/window gates).
    """

    def __init__(self, cfg: PagedCacheConfig):
        self.cfg = cfg
        self.allocator = PageAllocator(cfg.num_pages, cfg.num_shards)
        self.tables = np.full((cfg.max_batch, cfg.max_pages_per_seq),
                              TRASH_PAGE, np.int32)
        self.kv_len = np.zeros((cfg.max_batch,), np.int32)
        self._owned: Dict[int, Dict[int, int]] = {}  # slot → {block → page}
        self.pages_grown = 0        # lazily-allocated decode pages (stats)
        self.pages_reclaimed = 0    # out-of-window pages freed early (stats)

    def free_slots(self) -> List[int]:
        """Decode slots not currently backing a sequence."""
        return [s for s in range(self.cfg.max_batch) if s not in self._owned]

    def admit(self, slot: int, n_tokens: int, first_block: int = 0) -> bool:
        """Reserve the pages covering ``n_tokens`` at logical blocks
        ``first_block .. pages_for(n_tokens)-1``.

        Eager admission passes the full lifetime budget (prompt + gen);
        lazy admission passes only the prompt (decode pages come from
        :meth:`grow`).  Sliding-window admission skips blocks already dead
        on arrival via ``first_block`` — a resumed long-tail prompt then
        reserves only its O(window) live tail, not the whole prefix; prefill
        writes into skipped blocks land in the trash page (their table
        entries stay 0) and the kernels' window gate never reads them.
        All-or-nothing: False (no side effect) when the pool can't cover it.
        """
        assert slot not in self._owned
        if n_tokens > self.cfg.max_seq_len:
            raise ValueError(
                f"sequence of {n_tokens} tokens exceeds the block-table "
                f"capacity {self.cfg.max_seq_len} (raise max_pages_per_seq)")
        n_blocks = self.cfg.pages_for(n_tokens)
        assert 0 <= first_block < n_blocks
        pages = self.allocator.alloc(n_blocks - first_block)
        if pages is None:
            return False
        self._owned[slot] = {first_block + i: p for i, p in enumerate(pages)}
        self.tables[slot] = TRASH_PAGE
        self.tables[slot, first_block:n_blocks] = pages
        self.kv_len[slot] = 0
        return True

    def grow(self, slot: int) -> bool:
        """Ensure the next token's write block (``kv_len // page_size``) is
        owned, allocating one page if it isn't.  Idempotent; returns False
        (no side effect) when a page is needed but the pool is dry — the
        scheduler's cue to preempt."""
        blk = int(self.kv_len[slot]) // self.cfg.page_size
        owned = self._owned[slot]
        if blk in owned:
            return True
        if blk >= self.cfg.max_pages_per_seq:
            raise ValueError(
                f"slot {slot}: write position {int(self.kv_len[slot])} "
                f"escapes the block-table capacity {self.cfg.max_seq_len}")
        pages = self.allocator.alloc(1)
        if pages is None:
            return False
        owned[blk] = pages[0]
        self.tables[slot, blk] = pages[0]
        self.pages_grown += 1
        return True

    def reclaim_out_of_window(self, slot: int, window: int) -> List[int]:
        """Free this row's blocks that have slid fully out of a sliding
        attention window; returns the freed page ids.

        At the next decode step the query sits at position ``kv_len`` and the
        kernels admit keys at positions ``kp > kv_len - window`` (the same
        gate in the Pallas grid skip and the XLA fallback mask).  A block is
        dead once its *last* position ``(blk+1)·page_size - 1`` falls at or
        below ``kv_len - window`` — and stays dead, since ``kv_len`` only
        grows.  Its table entry reverts to the trash page, which the window
        gate skips without reading.
        """
        owned = self._owned.get(slot)
        if not owned:
            return []
        ps = self.cfg.page_size
        horizon = int(self.kv_len[slot]) - window  # last masked-out position
        freed = []
        for blk in sorted(owned):
            if (blk + 1) * ps - 1 > horizon:
                break                      # blocks are dead low-end-first
            freed.append(owned.pop(blk))
            self.tables[slot, blk] = TRASH_PAGE
        if freed:
            self.allocator.free(freed)
            self.pages_reclaimed += len(freed)
        return freed

    def release(self, slot: int):
        """Return every page a slot owns (finish, EOS, or preemption)."""
        self.allocator.free(list(self._owned.pop(slot).values()))
        self.tables[slot] = TRASH_PAGE
        self.kv_len[slot] = 0

    def prefill_dest(self, segment_ids_row: np.ndarray,
                     slots: List[int]) -> np.ndarray:
        """Flat page-pool token slots for one packed prefill row.

        segment_ids_row [S]: ids 0..n-1 over contiguous prompt spans, -1 pad;
        slots[i]: the cache slot backing segment i.  Returns dest [S] int32 —
        token t of segment i lands in ``table[t // ps] * ps + t % ps`` of slot
        ``slots[i]``'s table; padding lands in the trash page's slot 0.
        """
        ps = self.cfg.page_size
        dest = np.zeros(segment_ids_row.shape, np.int32)  # pad → trash slot 0
        for i, slot in enumerate(slots):
            (pos,) = np.nonzero(segment_ids_row == i)
            local = np.arange(len(pos))
            dest[pos] = self.tables[slot, local // ps] * ps + local % ps
        return dest

    def append_dest_ok(self, slot: int) -> bool:
        """Does the next token's write position fall inside an owned page?"""
        blk = int(self.kv_len[slot]) // self.cfg.page_size
        return blk in self._owned.get(slot, {})

    def utilization(self) -> Dict[str, float]:
        """Live tokens vs. reserved page capacity — the admission-policy
        metric: eager full-budget reservation holds pages long before tokens
        exist, lazy growth tracks the live set (and reclamation drops tokens
        that slid out of the window along with their pages)."""
        ps = self.cfg.page_size
        allocated = sum(len(p) for p in self._owned.values())
        cap = allocated * ps
        used = 0                     # tokens resident in *owned* pages
        for slot, owned in self._owned.items():
            n = int(self.kv_len[slot])
            used += sum(max(0, min(ps, n - blk * ps)) for blk in owned)
        return {
            "used_tokens": float(used),
            "allocated_tokens": float(cap),
            "allocated_pages": float(allocated),
            "pool_pages": float(self.cfg.usable_pages),
            "utilization": used / cap if cap else 0.0,
            "pool_fraction": allocated / self.cfg.usable_pages,
        }
