"""Deterministic fault injection for the serving engine ("chaos" harness).

A :class:`FaultPlan` is a seeded, replayable schedule of host-layer faults:
the plan is generated once from a ``numpy`` PRNG seed (same seed → the
exact same event tuple, byte for byte), and the engine consults it at the
top of every iteration.  The plan only *decides* — picking steps, kinds,
and victim indices — while the engine *applies* each event at the existing
host-layer seams (allocator, scheduler, state cache, decode logits), so
injection never perturbs the jitted device steps.

Fault kinds (``FaultEvent.kind``):

* ``"exhaust"`` — grab every free page from the allocator into a side
  pocket for ``pocket_hold`` steps, forcing growth failures / preemption
  exactly as a saturated pool would.
* ``"storm"``   — preempt the youngest eligible active rows (a preemption
  storm), exercising resume-from-prefix paths.
* ``"poison"``  — overwrite currently *free* pages and *free* state rows
  with huge garbage on device, proving reclaimed storage is never read.
* ``"nan"``     — corrupt one slot's decode logits with NaN for one step;
  the engine's health sentinel must quarantine that row (``FAILED``).
* ``"cancel"``  — cancel a live request mid-flight via the public
  :meth:`~repro.serving.engine.ServingEngine.cancel` API.

``crash_step`` additionally raises :class:`InjectedCrash` at the top of
that iteration, after which the host state can be snapshotted and a fresh
engine restored to resume token-identically (pinned in ``tests/test_chaos.py``).

Host layer: plain numpy/python, no jax (sparklint ``host-layer-numpy-only``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

KINDS: Tuple[str, ...] = ("exhaust", "storm", "poison", "nan", "cancel")


class InjectedCrash(RuntimeError):
    """Raised by the engine when a FaultPlan's ``crash_step`` fires.

    Deliberately *not* a typed request outcome: a crash kills the process
    mid-flight, and recovery is snapshot/restore, not per-request
    bookkeeping.  The engine releases any fault pocket before raising so
    pool conservation holds at the crash boundary.
    """


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fire at iteration ``step``, of kind ``kind``.

    ``arg`` disambiguates the victim where one is needed — storm width for
    ``"storm"``, a live-rid index for ``"cancel"``, a consumed-slot index
    for ``"nan"``; unused otherwise.
    """
    step: int
    kind: str
    arg: int = 0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, replayable schedule of :class:`FaultEvent`s.

    Constructing two plans with the same ``(seed, horizon, events_per_kind,
    kinds, crash_step, pocket_hold)`` yields identical ``events`` tuples —
    the determinism contract the chaos tests pin.  Pass ``events=``
    explicitly to hand-author a plan (seed is then ignored for scheduling
    but still recorded).
    """
    seed: int = 0
    horizon: int = 64
    events_per_kind: int = 2
    kinds: Tuple[str, ...] = KINDS
    crash_step: Optional[int] = None
    pocket_hold: int = 3
    events: Tuple[FaultEvent, ...] = dataclasses.field(default=None)  # type: ignore[arg-type]

    def __post_init__(self):
        for k in self.kinds:
            if k not in KINDS:
                raise ValueError(f"unknown fault kind {k!r} (known: {KINDS})")
        if self.events is None:
            object.__setattr__(self, "events", self._generate())
        else:
            object.__setattr__(self, "events", tuple(sorted(
                self.events, key=lambda e: (e.step, e.kind, e.arg))))

    def _generate(self) -> Tuple[FaultEvent, ...]:
        rs = np.random.RandomState(self.seed)
        out: List[FaultEvent] = []
        for kind in self.kinds:
            # Skip step 0 so every run admits at least one wave cleanly.
            steps = rs.randint(1, max(2, self.horizon), size=self.events_per_kind)
            args = rs.randint(0, 8, size=self.events_per_kind)
            out.extend(FaultEvent(int(s), kind, int(a))
                       for s, a in zip(steps, args))
        return tuple(sorted(out, key=lambda e: (e.step, e.kind, e.arg)))

    def events_at(self, step: int) -> List[FaultEvent]:
        """All events scheduled for engine iteration ``step``."""
        return [e for e in self.events if e.step == step]

    def describe(self) -> Dict[str, object]:
        """JSON-friendly summary for benchmark artifacts and logs."""
        return {
            "seed": self.seed,
            "crash_step": self.crash_step,
            "pocket_hold": self.pocket_hold,
            "events": [[e.step, e.kind, e.arg] for e in self.events],
        }


def plan_for_seeds(seeds: Sequence[int], **kwargs) -> List[FaultPlan]:
    """One plan per seed with shared knobs — the fuzz-matrix helper."""
    return [FaultPlan(seed=int(s), **kwargs) for s in seeds]
