"""Paged-KV serving subsystem: block-table caches + continuous batching.

The decode-time KV cache is the dominant HBM tensor in serving; contiguous
per-sequence caches must reserve ``max_batch × max_seq_len`` slots however
short the actual requests are.  This package stores KV in fixed-size *pages*
allocated on admission and freed on completion, with per-sequence block
tables mapping logical KV blocks → physical pages (vLLM's PagedAttention
idea, built on this repo's scalar-prefetch ragged-skip machinery):

* ``paged_cache``  — refcounted page allocator, content-addressed prefix
                     index, block tables (per-block ownership: lazy growth,
                     out-of-window reclamation, prefix sharing with
                     copy-on-write), scatter math.
* ``state_cache``  — per-slot recurrent-state bookkeeping for hybrid
                     SSM/recurrent archs (mamba, rgLRU): O(1) state rows
                     managed next to the page pool, admitted/released by
                     the same scheduler decisions that bind a slot's pages.
* ``drafter``      — prompt-lookup (n-gram) draft proposer + the greedy
                     longest-prefix acceptance rule for speculative decoding
                     (``ServingEngine(speculate_k=...)``); no second model.
* ``scheduler``    — FCFS continuous batching as an admission → grow →
                     preempt → re-prefill state machine: eager (full-budget
                     reservation) or lazy (prompt-only admission, one-page
                     decode growth, youngest-row preemption when the pool
                     runs dry).  See docs/scheduling.md.
* ``engine``       — the serving loop: segment-aware packed prefill (one
                     fused forward fills many prompts' pages, PR-1 varlen
                     masking) + block-table flash-decode each step, with
                     sliding-window page reclamation between steps; opt-in
                     prefix caching (``share_prefix=True``) and chunked
                     prefill (``prefill_chunk=``) ride on one extra jitted
                     step that prefills suffix spans against cached pages.
* ``outcomes``     — the typed request-outcome taxonomy (``COMPLETED |
                     CANCELLED | TIMEOUT | SHED | FAILED``): every request
                     the engine accepts terminates in exactly one.
* ``faults``       — seeded, replayable fault injection (``FaultPlan``)
                     at the host-layer seams: pool exhaustion, preemption
                     storms, freed-page/state poisoning, NaN logits,
                     crash-at-step-N + snapshot/restore.  The chaos
                     harness behind tests/test_chaos.py and
                     benchmarks/serving_chaos.py.

Kernel-level entry points live in ``core.attention.spark_paged_decode`` and
``kernels/decode.py::flash_paged_decode``; jitted model steps come from
``runtime.steps.make_serve_steps(..., paged=PagedCacheConfig(...))``.
Distributed serving (page-aligned pool sharding + partial-merge decode)
lives in ``distributed/paged.py`` — pass ``mesh=`` to the engine/steps.
See docs/serving.md for the design and a quickstart.
"""

from repro.serving.drafter import NgramDrafter, longest_accept
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultEvent, FaultPlan, InjectedCrash
from repro.serving.outcomes import (Outcome, RequestResult, outcome_counts,
                                    untyped_rids)
from repro.serving.paged_cache import (BlockTables, PageAllocator,
                                       PagedCacheConfig, PrefixIndex,
                                       TRASH_PAGE)
from repro.serving.scheduler import (AdmissionImpossible, ActiveSeq, Request,
                                     Scheduler)
from repro.serving.state_cache import StateCache

__all__ = [
    "ServingEngine", "BlockTables", "PageAllocator", "PagedCacheConfig",
    "PrefixIndex", "TRASH_PAGE", "ActiveSeq", "Request", "Scheduler",
    "NgramDrafter", "longest_accept", "StateCache", "AdmissionImpossible",
    "Outcome", "RequestResult", "outcome_counts", "untyped_rids",
    "FaultEvent", "FaultPlan", "InjectedCrash",
]
