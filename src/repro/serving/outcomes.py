"""Typed request outcomes: how every serving request terminates.

The resilience contract (docs/serving.md, "Resilience") is that **every**
request the engine ever accepts ends in exactly one typed outcome — there
is no way for a request to vanish from the books, hang forever, or fail
with an engine-wide exception that takes its batch-mates down with it:

* ``COMPLETED`` — ran to its budget or emitted EOS; its tokens are in the
  ``run()`` output dict keyed by rid (the pre-resilience behaviour).
* ``CANCELLED`` — removed by :meth:`~repro.serving.engine.ServingEngine.cancel`
  (waiting or mid-flight); partial tokens are kept in the result record.
* ``TIMEOUT``   — exceeded its wall-clock deadline or its engine-step
  budget; slot/pages/state reclaimed immediately, partial tokens kept.
* ``SHED``      — rejected at submit: the bounded admission queue was full
  (reject-newest backpressure) or the request's worst-case page footprint
  can never fit the pool (``AdmissionImpossible``).  Never occupied a slot.
* ``FAILED``    — quarantined by a health sentinel (non-finite decode
  logits) or killed by the livelock watchdog, with a diagnostic ``reason``.

This module is part of the serving host layer (sparklint's
``host-layer-numpy-only`` rule covers it): plain numpy/python, no jax.  The
companion sparklint rule ``engine-outcome-taxonomy`` enforces that every
engine code path removing an active sequence records one of these.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, List

import numpy as np


class Outcome(enum.Enum):
    """The five terminal states of a serving request (module docstring)."""
    COMPLETED = "completed"
    CANCELLED = "cancelled"
    TIMEOUT = "timeout"
    SHED = "shed"
    FAILED = "failed"


@dataclasses.dataclass
class RequestResult:
    """One request's terminal record: outcome, tokens produced, diagnosis.

    ``tokens`` holds whatever the request generated before terminating —
    the full generation for ``COMPLETED``, a partial one for
    ``CANCELLED``/``TIMEOUT``/``FAILED``, empty for ``SHED``.  ``reason``
    is a human-readable diagnostic for the non-completed outcomes (which
    deadline fired, what the watchdog saw, which sentinel tripped).
    """
    rid: int
    outcome: Outcome
    tokens: np.ndarray
    reason: str = ""

    @staticmethod
    def make(rid: int, outcome: Outcome, tokens: Iterable[int],
             reason: str = "") -> "RequestResult":
        """Build a record, normalizing ``tokens`` to an int32 array."""
        return RequestResult(rid=rid, outcome=outcome,
                             tokens=np.asarray(list(tokens), np.int32),
                             reason=reason)


def outcome_counts(results: Dict[int, RequestResult]) -> Dict[str, int]:
    """Per-outcome totals over a result map — the ``stats["outcomes"]``
    payload and the launcher's final report line.  Every outcome appears
    (zero-filled), so consumers can index unconditionally."""
    counts = {o.value: 0 for o in Outcome}
    for res in results.values():
        counts[res.outcome.value] += 1
    return counts


def untyped_rids(submitted: Iterable[int],
                 results: Dict[int, RequestResult]) -> List[int]:
    """Submitted rids with no terminal record — the chaos harness's
    zero-untyped-outcomes assertion (must always return ``[]`` after
    ``run()`` drains)."""
    return sorted(set(submitted) - set(results))
