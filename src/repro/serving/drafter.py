"""Draft proposers + the greedy acceptance rule for speculative decoding.

Decode is latency-bound at batch 1: every step re-reads the whole weight/KV
working set from HBM to emit ONE token.  Speculative decoding drafts ``k``
cheap candidate tokens per sequence and verifies them all in a single
verify-k model call (``lm.paged_verify_step``), so one pass over the weights
can emit up to ``k + 1`` tokens.  Greedy verification makes the output
token-identical to plain single-step decode *by construction*: a draft is
accepted only where it equals the argmax the model itself would have
produced, and the first mismatch position falls back to that argmax.

This module is pure host-side numpy — no model, no device arrays:

* :class:`NgramDrafter` — prompt-lookup drafting (no second model): the last
  ``n``-gram of a row's token history (prompt + generated) is searched for a
  previous occurrence, and the tokens that followed it are proposed.  Agent
  traces, code and retrieval-augmented prompts repeat themselves, which is
  exactly when decode batches are small and the speedup matters.
* :func:`longest_accept` — the acceptance rule, factored out pure so the
  property tests can fuzz it against an oracle re-check.

The engine wires these into the serving loop via
``ServingEngine(speculate_k=...)``; see docs/serving.md.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np


class NgramDrafter:
    """Prompt-lookup drafter: propose the continuation of the most recent
    earlier occurrence of the history's trailing n-gram.

    For ``n`` from ``max_ngram`` down to ``min_ngram``, the last ``n`` tokens
    of the history are matched against every earlier position; the *most
    recent* earlier match wins (recency tracks the current generation loop
    better than the first occurrence), and the tokens that followed it are
    proposed, up to ``k``.  No match at any ``n`` proposes nothing — the
    verify step then degenerates to a plain decode step for that row.

    Proposals are a pure function of the history (deterministic) and are
    drawn *from* the history, so they are always in-vocab — both properties
    are fuzz-tested in tests/test_speculative.py.
    """

    def __init__(self, k: int, max_ngram: int = 3, min_ngram: int = 1):
        """k: max tokens proposed per call.  max_ngram/min_ngram: the match
        lengths tried, longest first (longer matches are more specific, so
        their continuations are likelier to be accepted)."""
        if k < 1:
            raise ValueError(f"drafter needs k >= 1, got {k}")
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(f"need 1 <= min_ngram <= max_ngram, got "
                             f"{min_ngram}..{max_ngram}")
        self.k = k
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, history: np.ndarray, max_tokens: int = -1) -> np.ndarray:
        """Draft up to ``min(k, max_tokens)`` tokens continuing ``history``
        (the row's prompt + everything generated so far).  Returns an int32
        array, possibly empty (no n-gram recurrence found)."""
        history = np.asarray(history, np.int32)
        limit = self.k if max_tokens < 0 else min(self.k, max_tokens)
        n_hist = int(history.shape[0])
        if limit < 1 or n_hist < self.min_ngram + 1:
            return np.zeros(0, np.int32)
        for n in range(min(self.max_ngram, n_hist - 1), self.min_ngram - 1,
                       -1):
            pattern = history[n_hist - n:]
            # candidate start positions strictly before the trailing n-gram
            # itself; scan from the most recent backwards
            windows = np.lib.stride_tricks.sliding_window_view(
                history[:n_hist - 1], n)
            hits = np.nonzero((windows == pattern).all(axis=1))[0]
            if hits.size:
                start = int(hits[-1]) + n
                return history[start:start + limit].copy()
        return np.zeros(0, np.int32)


def longest_accept(draft: np.ndarray, greedy: np.ndarray
                   ) -> Tuple[int, List[int]]:
    """The speculative acceptance rule, pure and oracle-checkable.

    ``draft`` holds the ``k`` proposed tokens; ``greedy[j]`` is the argmax
    the verify pass produced at drafted position ``j`` — i.e. the token a
    plain greedy decode would emit after consuming ``draft[:j]`` (``greedy``
    has ``k + 1`` entries: one per drafted position plus the bonus token
    scored after the last draft).  Returns ``(accepted, emitted)`` where
    ``accepted`` is the length of the longest prefix with
    ``draft[j] == greedy[j]`` and ``emitted = greedy[:accepted + 1]`` — the
    accepted drafts (which *are* the greedy tokens, by the match) plus the
    model's own token at the first mismatch (or the bonus token when every
    draft survived).  ``k = 0`` degenerates to exactly one plain decode
    step: nothing accepted, ``emitted = [greedy[0]]``.
    """
    draft = np.asarray(draft, np.int32)
    greedy = np.asarray(greedy, np.int32)
    k = int(draft.shape[0])
    assert greedy.shape[0] == k + 1, \
        f"verify must score k+1 positions, got {greedy.shape[0]} for k={k}"
    accepted = 0
    while accepted < k and draft[accepted] == greedy[accepted]:
        accepted += 1
    return accepted, [int(t) for t in greedy[:accepted + 1]]
