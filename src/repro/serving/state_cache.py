"""Per-slot recurrent-state bookkeeping for hybrid SSM/recurrent serving.

Attention state is O(tokens) and lives in the paged pool; recurrent state
(mamba's conv tail + SSM ``h``, rgLRU's hidden ``h`` + conv tail) is O(1)
per sequence — the degenerate case of sliding-window reclamation where the
"window" is a single carried state.  The device side is one fixed row per
decode slot in every recurrent layer's cache (plus one trailing *trash row*
that absorbs padding-token gathers/scatters, mirroring the pool's trash
page); this class owns the host side: which slots hold live state, which
are free, and which were just released and must never be read again.

Lifecycle is driven by :class:`~repro.serving.paged_cache.BlockTables` —
``admit`` / ``release`` there call ``admit`` / ``release`` here, so the
scheduler's existing admission/eviction/preemption decisions manage
recurrent state with no extra policy.  Correctness does **not** depend on
host-side zeroing: a prefill span starting at position 0 always injects a
fresh zero initial state on device, so a re-admitted slot's stale state is
dead by construction.  ``drain_released`` exists for the engine's
``poison_reclaimed`` test hook, which clobbers released rows with a huge
constant so any read of dead state corrupts generations instead of passing
silently.

Conservation invariant (fuzz-tested in tests/test_paged.py):
``num_free + num_occupied == capacity`` with the two sets disjoint.

Plain python only — this module is part of the serving host layer
(sparklint's ``host-layer-numpy-only`` rule covers it): no jax imports, no
device buffers, nothing that could trace or recompile per queue shape.
"""

from __future__ import annotations

from typing import List


class StateCache:
    """Host bookkeeping for the fixed per-slot recurrent-state rows.

    ``capacity`` equals the engine's ``max_batch``: state row ``i`` on
    device backs decode slot ``i`` (the device arrays carry one extra
    trailing trash row this class never tracks).
    """

    def __init__(self, capacity: int):
        assert capacity >= 1, "need at least one state slot"
        self.capacity = capacity
        self._free = set(range(capacity))
        self._occupied = set()
        self._released: List[int] = []   # drained by the engine's poison hook
        self.admits = 0
        self.releases = 0

    @property
    def num_free(self) -> int:
        """Slots whose state row is dead (writable by the next admission)."""
        return len(self._free)

    @property
    def num_occupied(self) -> int:
        """Slots whose state row backs a live sequence."""
        return len(self._occupied)

    def occupied(self, slot: int) -> bool:
        """Is this slot's state row live?"""
        return slot in self._occupied

    def free_slot_ids(self) -> List[int]:
        """Snapshot of the free slots (state rows that are dead).  The
        chaos harness's ``poison`` fault clobbers exactly these rows to
        prove released recurrent state is never read back."""
        return sorted(self._free)

    def admit(self, slot: int):
        """Mark a slot's state row live.  Raises on a slot outside the
        capacity or already occupied (the double-admit that would silently
        smear two sequences' recurrent state)."""
        if not 0 <= slot < self.capacity:
            raise ValueError(f"state slot {slot} outside capacity "
                             f"{self.capacity}")
        if slot in self._occupied:
            raise ValueError(f"state slot {slot} is already occupied — "
                             f"double admit")
        self._free.remove(slot)
        self._occupied.add(slot)
        self.admits += 1

    def release(self, slot: int):
        """Mark a slot's state row dead (finish, EOS, or preemption) and
        queue it for :meth:`drain_released`.  Raises on a slot that is not
        occupied (double release / never admitted)."""
        if slot not in self._occupied:
            raise ValueError(f"state slot {slot} is not occupied — double "
                             f"release or never admitted")
        self._occupied.remove(slot)
        self._free.add(slot)
        self._released.append(slot)
        self.releases += 1

    def drain_released(self) -> List[int]:
        """Take the slots released since the last drain (in release order).
        The engine's ``poison_reclaimed`` hook clobbers these rows on
        device; a drained slot may already be re-admitted, in which case
        poisoning is still safe — re-admission re-prefills from position 0,
        which injects a fresh zero state without reading the row."""
        out = self._released
        self._released = []
        return out
