"""Mixture-of-Experts FFN with GShard-style grouped einsum dispatch.

Expert-parallel layout: tokens are grouped (``group_size`` per arch config),
groups shard over the data axis, experts shard over the model axis. The
dispatch/combine einsums are the standard GShard/Switch formulation — fully
GSPMD-shardable, capacity-factor token dropping, dropped-fraction surfaced as a
metric. ``shared_experts`` (deepseek-moe) run as a dense MLP on every token.

The dense-loop oracle (`moe_reference`) is used by tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def init_moe(key, cfg, dtype):
    m = cfg.moe
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    p, s = {}, {}
    p["router"], s["router"] = layers.dense_init(
        ks[0], d, e, jnp.float32, "embed", "experts", scale=d ** -0.5)
    wi = jax.random.normal(ks[1], (e, d, 2 * f), jnp.float32) * (d ** -0.5)
    wo = jax.random.normal(ks[2], (e, f, d), jnp.float32) * (f ** -0.5)
    p["wi"], s["wi"] = wi.astype(dtype), ("experts", "embed", "expert_mlp")
    p["wo"], s["wo"] = wo.astype(dtype), ("experts", "expert_mlp", "embed")
    if m.num_shared_experts:
        p["shared"], s["shared"] = layers.init_mlp(
            ks[3], d, m.num_shared_experts * f, dtype, gated=True)
    return p, s


def _capacity(group_size: int, num_experts: int, top_k: int, factor: float) -> int:
    c = int(group_size * top_k * factor / num_experts)
    return max(8, (c + 7) // 8 * 8)  # 8-aligned for TPU sublanes


def apply_moe(p, x, ctx: layers.Ctx, cfg):
    """x: [B, S, d] -> [B, S, d]. Router in f32 for stable softmax."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    tokens = x.reshape(b * s, d)
    n_tok = tokens.shape[0]
    g_sz = min(m.group_size, n_tok)
    while n_tok % g_sz:  # largest divisor ≤ configured group size
        g_sz -= 1
    n_g = n_tok // g_sz
    xg = tokens.reshape(n_g, g_sz, d)
    xg = ctx.c(xg, "moe_groups", None, "embed")

    logits = (xg.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # [G, S, E]
    gate_vals, idx = jax.lax.top_k(probs, k)                 # [G, S, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    cap = _capacity(g_sz, e, k, m.capacity_factor)
    onehot_e = jax.nn.one_hot(idx, e, dtype=jnp.float32)     # [G, S, k, E]
    # position of each (token, choice) in its expert's queue, in token order
    # (priority to earlier tokens, then lower-rank choices — GShard semantics)
    flat = onehot_e.reshape(n_g, g_sz * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                    # [G, S*k, E]
    pos = jnp.sum(pos.reshape(n_g, g_sz, k, e) * onehot_e, axis=-1)  # [G, S, k]
    keep = pos < cap
    gate_vals = gate_vals * keep                              # drop over-capacity
    onehot_c = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                              dtype=jnp.float32) * keep[..., None]

    # dispatch [G, S, E, C] — contracted immediately by the einsums below
    dispatch = jnp.einsum("gske,gskc->gsec", onehot_e, onehot_c)
    combine = jnp.einsum("gsk,gske,gskc->gsec", gate_vals, onehot_e, onehot_c)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    xe = ctx.c(xe, "moe_groups", "experts", None, "embed")
    h = jnp.einsum("gecd,edf->gecf", xe, p["wi"])
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    h = ctx.c(h, "moe_groups", "experts", None, "expert_mlp")
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    ye = ctx.c(ye, "moe_groups", "experts", None, "embed")
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)

    out = out.reshape(b, s, d)
    if m.num_shared_experts:
        out = out + layers.apply_mlp(p["shared"], x, ctx, gated=True)
    # aux metrics: load-balance loss (Switch) + dropped fraction
    density = jnp.mean(onehot_e.sum(2), axis=1)              # [G, E] token frac
    router_mean = jnp.mean(probs, axis=1)                    # [G, E]
    aux_loss = e * jnp.mean(jnp.sum(density * router_mean, axis=-1))
    dropped = 1.0 - jnp.sum(keep) / (n_g * g_sz * k)
    return ctx.c(out, "batch", "seq", "embed"), {"moe_aux": aux_loss,
                                                 "moe_dropped": dropped}


def moe_reference(p, x, cfg):
    """Dense per-expert loop oracle (no capacity drop) for tiny test shapes."""
    m = cfg.moe
    b, s, d = x.shape
    tokens = x.reshape(b * s, d).astype(jnp.float32)
    logits = tokens @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    out = jnp.zeros_like(tokens)
    for ex in range(m.num_experts):
        hi = tokens @ p["wi"][ex].astype(jnp.float32)
        g, u = jnp.split(hi, 2, axis=-1)
        y = (jax.nn.silu(g) * u) @ p["wo"][ex].astype(jnp.float32)
        w = jnp.sum(jnp.where(idx == ex, gate_vals, 0.0), axis=-1)
        out = out + w[:, None] * y
    out = out.reshape(b, s, d).astype(x.dtype)
    if m.num_shared_experts:
        ctx = layers.Ctx()
        out = out + layers.apply_mlp(p["shared"], x, ctx, gated=True)
    return out
