"""Model assembly: embeddings → scanned block stack → head, for all families.

Layer stacks are grouped into *superblocks* of ``len(block_pattern)`` layers and
scanned with ``jax.lax.scan`` over stacked params (compact HLO at 95 layers;
remat per superblock). Heterogeneous patterns (recurrentgemma's rec/rec/attn)
scan over the superblock period; trailing ``L % period`` layers run unscanned.

Public entry points:
  init_params(cfg, key, vocab_pad_to)      → (params, logical specs)
  forward(cfg, params, ctx, ...)           → (logits, caches, metrics)
  loss_fn / train metrics
  init_cache / prefill / decode_step       → KV-cache & recurrent-state serving
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers, mamba, moe, rglru
from repro.models.layers import Ctx

FRONTEND_DIM = 1024  # stub frontends hand us precomputed 1024-d patch/frame embeds


# ---------------------------------------------------------------------------
# per-block init/apply
# ---------------------------------------------------------------------------

def _init_block(key, cfg, kind: str, dtype):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), dtype)}
    s: Dict[str, Any] = {"norm1": ("embed",)}
    if kind == "attn":
        p["mixer"], s["mixer"] = layers.init_attention(ks[0], cfg, dtype)
    elif kind == "rec":
        p["mixer"], s["mixer"] = rglru.init_rglru(ks[0], cfg, dtype)
    elif kind == "ssm":
        p["mixer"], s["mixer"] = mamba.init_mamba(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if kind != "ssm":  # mamba blocks are mixer-only (d_ff = 0)
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        s["norm2"] = ("embed",)
        if cfg.moe is not None:
            p["mlp"], s["mlp"] = moe.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"], s["mlp"] = layers.init_mlp(
                ks[1], cfg.d_model, cfg.d_ff, dtype,
                gated=(cfg.mlp_type == "gated_silu"))
    return p, s


def _apply_block(p, x, ctx: Ctx, cfg, kind: str, *, positions, cache,
                 layer_seed, segment_ids=None, paged=None):
    metrics = {}
    h = layers.rms_norm(x, p["norm1"])
    if kind == "attn":
        mixed, new_cache = layers.apply_attention(
            p["mixer"], h, ctx, cfg, positions=positions, cache=cache,
            layer_seed=layer_seed, segment_ids=segment_ids, paged=paged)
    elif kind == "rec":
        mixed, new_cache = rglru.apply_rglru(p["mixer"], h, ctx, cfg,
                                             cache=cache, positions=positions,
                                             paged=paged)
    else:
        mixed, new_cache = mamba.apply_mamba(p["mixer"], h, ctx, cfg,
                                             cache=cache, positions=positions,
                                             paged=paged)
    x = x + mixed
    if "mlp" in p:
        h = layers.rms_norm(x, p["norm2"])
        if cfg.moe is not None:
            out, metrics = moe.apply_moe(p["mlp"], h, ctx, cfg)
        else:
            out = layers.apply_mlp(p["mlp"], h, ctx,
                                   gated=(cfg.mlp_type == "gated_silu"))
        x = x + out
    return x, new_cache, metrics


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------

def padded_vocab(cfg, vocab_pad_to: int) -> int:
    return -(-cfg.vocab_size // vocab_pad_to) * vocab_pad_to


def init_params(cfg, key, *, vocab_pad_to: int = 1):
    period = len(cfg.block_pattern)
    n_super, rem = divmod(cfg.num_layers, period)
    vpad = padded_vocab(cfg, vocab_pad_to)
    keys = jax.random.split(key, 4 + cfg.num_layers)
    dtype = cfg.dtype

    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"] = (jax.random.normal(keys[0], (vpad, cfg.d_model),
                                         jnp.float32) * 0.02).astype(dtype)
    specs["embed"] = ("vocab", "embed")
    if cfg.frontend is not None:
        params["frontend_proj"], specs["frontend_proj"] = layers.dense_init(
            keys[1], FRONTEND_DIM, cfg.d_model, dtype, None, "embed")
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    specs["final_norm"] = ("embed",)
    params["lm_head"], specs["lm_head"] = layers.dense_init(
        keys[2], cfg.d_model, vpad, dtype, "embed", "vocab")

    # stacked superblocks: params["blocks"]["sub_j"][leaf][n_super, ...]
    def init_layer(i, k):
        kind = cfg.block_pattern[i % period]
        return _init_block(k, cfg, kind, dtype)

    if n_super > 0:
        subs_p, subs_s = {}, {}
        for j in range(period):
            layer_ids = [s_ * period + j for s_ in range(n_super)]
            ps = [init_layer(i, keys[4 + i]) for i in layer_ids]
            subs_p[f"sub_{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                              *[p for p, _ in ps])
            subs_s[f"sub_{j}"] = jax.tree.map(
                lambda ax: ("layers",) + tuple(ax) if isinstance(ax, tuple)
                else ax, ps[0][1], is_leaf=lambda x: isinstance(x, tuple))
        params["blocks"] = subs_p
        specs["blocks"] = subs_s
    tail_p, tail_s = {}, {}
    for r in range(rem):
        i = n_super * period + r
        tail_p[f"tail_{r}"], tail_s[f"tail_{r}"] = init_layer(i, keys[4 + i])
    if rem:
        params["tail"] = tail_p
        specs["tail"] = tail_s
    return params, specs


def abstract_params(cfg, *, vocab_pad_to: int = 1):
    """(ShapeDtypeStruct pytree, logical-spec pytree) with zero allocation."""
    box = {}

    def f(key):
        p, s = init_params(cfg, key, vocab_pad_to=vocab_pad_to)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["specs"]


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block_kinds(cfg):
    period = len(cfg.block_pattern)
    n_super, rem = divmod(cfg.num_layers, period)
    return period, n_super, rem


def forward(cfg, params, ctx: Ctx, *, tokens=None, embeds=None, caches=None,
            positions=None, segment_ids=None, paged=None):
    """tokens [B,S] int32 OR embeds [B,S,FRONTEND_DIM]. Returns
    (logits [B,S,Vpad], new_caches, metrics).

    segment_ids [B,S]: packed-batch segment ids — attention blocks mask
    cross-segment pairs; pass per-segment ``positions`` alongside so RoPE
    restarts per packed sequence. Recurrent/SSM blocks carry state across
    the whole row regardless (packing is an attention-family feature).

    paged: paged-cache routing info forwarded to every attention block —
    {"dest": [B,S]} for packed prefill, {"block_tables": [B,T],
    "kv_len": [B]} for decode (see serving/paged_cache.py)."""
    period, n_super, rem = _block_kinds(cfg)
    if embeds is not None:
        x = embeds.astype(cfg.dtype) @ params["frontend_proj"]
    else:
        x = params["embed"][tokens]
    x = ctx.c(x, "batch", "seq", "embed")
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)

    metrics_acc = {"moe_aux": jnp.float32(0.0), "moe_dropped": jnp.float32(0.0)}
    n_moe = 0

    def apply_super(x, super_params, super_caches, super_idx):
        new_caches = {}
        mets = []
        for j in range(period):
            kind = cfg.block_pattern[j]
            cache_j = None if super_caches is None else super_caches[f"sub_{j}"]
            seed_off = super_idx * period + j
            x, nc, m = _apply_block(super_params[f"sub_{j}"], x, ctx, cfg, kind,
                                    positions=positions, cache=cache_j,
                                    layer_seed=seed_off * 1000003,
                                    segment_ids=segment_ids, paged=paged)
            new_caches[f"sub_{j}"] = nc
            if m:
                mets.append(m)
        msum = {}
        if mets:
            msum = {k: sum(m[k] for m in mets) for k in mets[0]}
        return x, new_caches, msum

    if n_super > 0:
        has_cache = caches is not None

        def scan_body(x, inp):
            idx, super_params, super_caches = inp
            x, nc, m = apply_super(x, super_params, super_caches, idx)
            if not m:
                m = {"moe_aux": jnp.float32(0.0),
                     "moe_dropped": jnp.float32(0.0)}
            out = (nc, m) if has_cache else (None, m)
            return x, out

        cache_stack = caches["blocks"] if has_cache else None
        if cfg.scan_layers:
            body = scan_body
            if cfg.remat:
                body = jax.checkpoint(scan_body,
                                      prevent_cse=False)  # remat/superblock
            idxs = jnp.arange(n_super)
            x, (new_cache_stack, ms) = jax.lax.scan(
                body, x, (idxs, params["blocks"], cache_stack))
        else:
            # unrolled stack (dry-run cost pass): identical math, flat HLO
            ncs, mss = [], []
            for i in range(n_super):
                sp = jax.tree.map(lambda a: a[i], params["blocks"])
                sc = (None if cache_stack is None
                      else jax.tree.map(lambda a: a[i], cache_stack))
                x, (nc, m) = scan_body(x, (jnp.int32(i), sp, sc))
                ncs.append(nc)
                mss.append(m)
            new_cache_stack = (jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
                               if has_cache else None)
            ms = jax.tree.map(lambda *xs: jnp.stack(xs), *mss)
        if cfg.moe is not None:
            metrics_acc["moe_aux"] += jnp.sum(ms["moe_aux"])
            metrics_acc["moe_dropped"] += jnp.sum(ms["moe_dropped"])
            n_moe += n_super * period
    else:
        new_cache_stack = None

    new_tail = {}
    for r in range(rem):
        i = n_super * period + r
        kind = cfg.block_pattern[i % period]
        cache_r = None if caches is None else caches["tail"][f"tail_{r}"]
        x, nc, m = _apply_block(params["tail"][f"tail_{r}"], x, ctx, cfg, kind,
                                positions=positions, cache=cache_r,
                                layer_seed=i * 1000003,
                                segment_ids=segment_ids, paged=paged)
        new_tail[f"tail_{r}"] = nc
        if m:
            metrics_acc["moe_aux"] += m["moe_aux"]
            metrics_acc["moe_dropped"] += m["moe_dropped"]
            n_moe += 1

    x = layers.rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    logits = ctx.c(logits, "batch", "seq", "vocab")

    if n_moe:
        metrics_acc["moe_dropped"] = metrics_acc["moe_dropped"] / n_moe
    new_caches = None
    if caches is not None:
        new_caches = {"blocks": new_cache_stack}
        if rem:
            new_caches["tail"] = new_tail
    return logits, new_caches, metrics_acc


# ---------------------------------------------------------------------------
# training loss
# ---------------------------------------------------------------------------

def loss_fn(cfg, params, batch, ctx: Ctx, *, aux_weight: float = 0.01):
    """batch: {'tokens' or 'embeds', 'labels'} (+ optional 'segment_ids',
    'positions' for packed batches). Next-token CE for causal LMs,
    per-position CE for encoders. Returns (loss, metrics)."""
    seg = batch.get("segment_ids")
    logits, _, metrics = forward(cfg, params, ctx,
                                 tokens=batch.get("tokens"),
                                 embeds=batch.get("embeds"),
                                 positions=batch.get("positions"),
                                 segment_ids=seg)
    labels = batch["labels"]
    weights = None
    if cfg.causal:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
        if seg is not None:
            # a segment's last token must not be trained to predict the next
            # segment's first token (and padding predicts nothing)
            weights = ((seg[:, :-1] == seg[:, 1:]) &
                       (seg[:, 1:] >= 0)).astype(jnp.float32)
    elif seg is not None:
        weights = (seg >= 0).astype(jnp.float32)
    ce = layers.softmax_cross_entropy(logits, labels, cfg.vocab_size,
                                      weights=weights)
    loss = ce
    if cfg.moe is not None:
        loss = loss + aux_weight * metrics["moe_aux"]
    metrics = dict(metrics, ce=ce, loss=loss)
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    period, n_super, rem = _block_kinds(cfg)

    def one(kind):
        if kind == "attn":
            # sliding-window archs only ever need `window` cache slots
            eff = max_len if cfg.attn_window is None else min(
                max_len, cfg.attn_window)
            return layers.init_attn_cache(cfg, batch, eff, dtype)
        if kind == "rec":
            return rglru.init_rglru_cache(cfg, batch)
        return mamba.init_mamba_cache(cfg, batch)

    caches = {}
    if n_super > 0:
        caches["blocks"] = {
            f"sub_{j}": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_super,) + x.shape).copy(),
                one(cfg.block_pattern[j]))
            for j in range(period)}
    if rem:
        caches["tail"] = {f"tail_{r}": one(cfg.block_pattern[
            (n_super * period + r) % period]) for r in range(rem)}
    return caches


def prefill(cfg, params, ctx: Ctx, tokens=None, embeds=None, caches=None):
    """Run the full prompt, filling caches. Returns (last_logits, caches)."""
    logits, caches, _ = forward(cfg, params, ctx, tokens=tokens, embeds=embeds,
                                caches=caches)
    return logits[:, -1], caches


def decode_step(cfg, params, ctx: Ctx, token, caches, position):
    """One autoregressive step. token [B] int32 → (logits [B,Vpad], caches)."""
    ctx = layers.Ctx(**{**ctx.__dict__, "decode": True})
    b = token.shape[0]
    positions = jnp.broadcast_to(position, (b, 1)).astype(jnp.int32)
    logits, caches, _ = forward(cfg, params, ctx, tokens=token[:, None],
                                caches=caches, positions=positions)
    return logits[:, 0], caches


# ---------------------------------------------------------------------------
# paged serving: page-pool cache / packed prefill / block-table decode
# ---------------------------------------------------------------------------

def init_paged_cache(cfg, paged_cfg, dtype=None):
    """Serving cache per layer kind: attention blocks get page pools
    [Hkv, num_pages, page_size, D] (no batch dim — sequences share the pool
    via block tables); recurrent/SSM blocks get fixed per-slot state rows
    [max_batch + 1, ...] — O(1) per sequence, slot i backing decode slot i,
    plus one trailing *trash row* (index -1) that absorbs padding-token
    gathers/scatters exactly like the pool's trash page.  Host-side slot
    lifecycle lives in serving/state_cache.py."""
    dtype = dtype or cfg.dtype
    period, n_super, rem = _block_kinds(cfg)

    def one(kind):
        if kind == "attn":
            return layers.init_paged_attn_cache(cfg, paged_cfg, dtype)
        rows = paged_cfg.max_batch + 1        # + the trailing trash row
        if kind == "rec":
            return rglru.init_rglru_cache(cfg, rows)
        return mamba.init_mamba_cache(cfg, rows)

    caches = {}
    if n_super > 0:
        caches["blocks"] = {
            f"sub_{j}": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_super,) + x.shape).copy(),
                one(cfg.block_pattern[j]))
            for j in range(period)}
    if rem:
        caches["tail"] = {f"tail_{r}": one(cfg.block_pattern[
            (n_super * period + r) % period]) for r in range(rem)}
    return caches


def paged_prefill(cfg, params, ctx: Ctx, tokens, segment_ids, positions, dest,
                  caches, state_slots=None):
    """Segment-aware packed prefill: many prompts in one fused forward.

    tokens/segment_ids/positions [B, S] (prompts packed along S, -1 = pad,
    per-prompt positions restarting at 0); dest [B, S] flat page-pool token
    slots from BlockTables.prefill_dest. Returns (logits [B, S, Vpad], caches)
    — the engine reads each prompt's last-token row.

    state_slots [B, S] (hybrid SSM/recurrent archs): each token's decode
    slot, -1 for padding — recurrent blocks reset their scan at every span
    start and scatter span-end state into the slot's state row.  Classic
    prefill spans always start at position 0, so the per-segment positions
    double as the within-span offsets (state_local).
    """
    paged = {"dest": dest}
    if state_slots is not None:
        paged.update(state_slots=state_slots, state_local=positions)
    logits, caches, _ = forward(cfg, params, ctx, tokens=tokens, caches=caches,
                                positions=positions, segment_ids=segment_ids,
                                paged=paged)
    return logits, caches


def paged_chunk_prefill(cfg, params, ctx: Ctx, tokens, positions, dest,
                        token_tables, token_kv_len, caches,
                        state_slots=None, state_local=None):
    """Chunked / suffix packed prefill: prompt spans whose earlier tokens
    already live in pages (prefix-cache hits, earlier chunks of the same
    prompt).

    tokens/positions [B, S] with *global* per-token positions (RoPE must
    match what the prefix pages were written with); dest [B, S] flat
    page-pool token slots (BlockTables.span_dest, padding → trash);
    token_tables [B, S, T] each token's slot's block-table row;
    token_kv_len [B, S] = position + 1 for real tokens, 0 for padding.
    Each layer scatters the span's K/V into the pages first, then every
    token attends through its own block-table row — history and same-row
    predecessors alike — so no segment ids are needed (isolation comes from
    the tables).  Returns (logits [B, S, Vpad], caches); the engine reads a
    prompt's last-token row when its final chunk lands.

    state_slots/state_local [B, S] (hybrid SSM/recurrent archs): each
    token's decode slot (-1 pad) and offset within its span — a span whose
    global start (position - local) is past 0 resumes the slot's stored
    recurrent state (the previous chunk's span-end scatter).
    """
    paged = {"dest": dest, "token_tables": token_tables,
             "token_kv_len": token_kv_len}
    if state_slots is not None:
        paged.update(state_slots=state_slots, state_local=state_local)
    logits, caches, _ = forward(
        cfg, params, ctx, tokens=tokens, caches=caches, positions=positions,
        paged=paged)
    return logits, caches


def paged_verify_step(cfg, params, ctx: Ctx, tokens, positions, dest,
                      token_tables, token_kv_len, caches):
    """Speculative verify: score ``k + 1`` tokens per decode row in ONE
    forward call — the row's current last token plus its ``k`` drafted
    continuations — amortizing the per-step weight/KV HBM reads over up to
    ``k + 1`` emitted tokens.

    Inputs mirror :func:`paged_chunk_prefill` with ``[B = max_batch,
    W = k + 1]`` rows instead of packed prompt spans: tokens/positions
    ``[B, W]`` (global positions ``kv_len .. kv_len + k``), dest ``[B, W]``
    flat page-pool scatter slots (draft padding and masked rows → the trash
    page), token_tables ``[B, W, T]``, token_kv_len ``[B, W]`` =
    ``position + 1`` for live tokens and 0 for padding.  Each layer scatters
    all drafted K/V first, then every token attends through its own
    block-table row at its absolute position — drafted queries see the
    drafted keys before them, which is exactly the conditioning greedy
    acceptance needs (serving/drafter.py ``longest_accept``).

    The host accepts the longest draft prefix matching the per-position
    argmaxes and advances ``kv_len`` past it; rejected drafts' scatter
    writes are rolled back *logically* — they sit at positions ``>= kv_len``
    which every kernel read gates out, and the next step re-scatters those
    positions before ``kv_len`` ever covers them (docs/serving.md spells
    out the invariant).  Returns (logits [B, W, Vpad], caches).
    """
    return paged_chunk_prefill(cfg, params, ctx, tokens, positions, dest,
                               token_tables, token_kv_len, caches)


def paged_decode_step(cfg, params, ctx: Ctx, token, caches, block_tables,
                      kv_len):
    """One decode step over the paged cache. token [B] int32, block_tables
    [B, T], kv_len [B] (current lengths; the new token lands at position
    kv_len, and the engine increments host-side). → (logits [B,Vpad], caches).
    """
    ctx = layers.Ctx(**{**ctx.__dict__, "decode": True})
    positions = kv_len[:, None].astype(jnp.int32)
    logits, caches, _ = forward(
        cfg, params, ctx, tokens=token[:, None], caches=caches,
        positions=positions,
        paged={"block_tables": block_tables, "kv_len": kv_len})
    return logits[:, 0], caches
