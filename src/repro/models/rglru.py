"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrence  h_t = a_t ⊙ h_{t-1} + √(1-a_t²) ⊙ (i_t ⊙ x_t)  is a linear
(elementwise, gated) scan — SparkAttention is inapplicable here (no QKᵀ /
softmax), so this mixer is pure JAX (docs/architecture.md). Training
uses an associative scan over the sequence; decode is a single state update.

Block layout (Griffin recurrent block):
  x → [linear → conv1d(4) → RG-LRU]  ⊙  [linear → gelu]  → linear out
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

_C = 8.0  # Griffin's fixed exponent scale for a_t


def init_rglru(key, cfg, dtype):
    d, dr = cfg.d_model, cfg.rglru.d_rnn
    ks = jax.random.split(key, 7)
    p, s = {}, {}
    p["wx"], s["wx"] = layers.dense_init(ks[0], d, dr, dtype, "embed", "rnn")
    p["wg"], s["wg"] = layers.dense_init(ks[1], d, dr, dtype, "embed", "rnn")
    p["wo"], s["wo"] = layers.dense_init(ks[2], dr, d, dtype, "rnn", "embed")
    # conv1d over time, kernel 4, per-channel (depthwise)
    p["conv"] = (jax.random.normal(ks[3], (4, dr), jnp.float32) * 0.1).astype(dtype)
    s["conv"] = (None, "rnn")
    # gates
    p["w_inp"], s["w_inp"] = layers.dense_init(ks[4], dr, dr, dtype, "rnn", "rnn")
    p["w_rec"], s["w_rec"] = layers.dense_init(ks[5], dr, dr, dtype, "rnn", "rnn")
    # Λ init so the retention a_t = exp(−c·r·softplus(Λ)) hits a ∈ (0.9,0.999)
    # at r=1 (Griffin's a_t = a^{c·r} with a = exp(−softplus(Λ)); softplus(Λ)
    # must equal −log(a)/c, so Λ = softplus⁻¹(−log a / c)).
    lam = jax.random.uniform(ks[6], (dr,), jnp.float32, 0.9, 0.999)
    target = -jnp.log(lam) / _C
    p["lambda"] = jnp.log(jnp.expm1(target))      # inverse softplus
    s["lambda"] = ("rnn",)
    return p, s


def _conv1d_causal(x, w, state=None):
    """Depthwise causal conv over time. x [B,S,D], w [K,D].

    state (decode): [B, K-1, D] previous inputs; returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):]
    return y, new_state


# ---------------------------------------------------------------------------
# packed per-slot state routing (paged serving prefill)
#
# A packed prefill row holds one or more prompt *spans* back to back (trailing
# padding).  Recurrent state must reset at every span start — and a span that
# resumes a sequence mid-prompt (chunked prefill) must resume from the state
# its slot's row stored after the previous chunk.  The helpers below are
# shared by the rgLRU and mamba mixers; both keep their serving state in rows
# [n_slots + 1, ...] where the trailing row (index -1) is a *trash row*
# absorbing padding-token gathers and non-end scatters, mirroring the page
# pool's trash page.
# ---------------------------------------------------------------------------

def _packed_seg(paged, positions):
    """Per-token span fields from the paged routing dict.

    paged["state_slots"] [B,S]: each token's decode slot (-1 = padding);
    paged["state_local"] [B,S]: its offset within its span.  Returns
    (slots, local, is_start, inject, is_end): span-start mask, carried-state
    injection mask (span starts that resume past global position 0), and
    span-end mask (the token whose state the caller scatters back)."""
    slots = paged["state_slots"]
    local = paged["state_local"]
    positions = jnp.broadcast_to(positions, slots.shape)
    live = slots >= 0
    is_start = live & (local == 0)
    cont = live & (positions - local > 0)     # span resumes mid-sequence
    nxt = jnp.concatenate([slots[:, 1:],
                           jnp.full_like(slots[:, :1], -1)], axis=1)
    is_end = live & (slots != nxt)
    return slots, local, is_start, is_start & cont, is_end


def _conv1d_causal_packed(x, w, state, slots, local, positions):
    """Packed multi-span depthwise causal conv with per-slot carried state.

    x [B,S,D]; w [K,D]; state [n_slots+1, K-1, D] (state[j] holds the input
    at lag K-1-j relative to the span start, trailing row = trash).  A lag-l
    read stays in-row while ``local >= l`` (spans are contiguous), falls back
    to the slot's carried state for continuation spans, and is zero for a
    fresh span's pre-history.  Returns (y, lags): lags[l] is each token's
    lag-l input — the caller stacks lags at span ends into the new conv
    state (state_new[j] = lag K-2-j, i.e. the history the *next* token
    would need)."""
    k = w.shape[0]
    s = x.shape[1]
    cont = (slots >= 0) & (positions - local > 0)
    lags = [x]
    for lag in range(1, k):
        in_row = jnp.pad(x, ((0, 0), (lag, 0), (0, 0)))[:, :s]
        j = jnp.clip(k - 1 - lag + local, 0, k - 2)
        carried = state[slots, j].astype(x.dtype)
        lags.append(jnp.where((local >= lag)[..., None], in_row,
                              jnp.where(cont[..., None], carried,
                                        jnp.zeros_like(x))))
    y = sum(w[k - 1 - lag] * lags[lag] for lag in range(k))
    return y, lags


def _conv_state_of(lags):
    """Stack per-token lag values into conv-state rows [B,S,K-1,D]
    (state_new[j] = lag K-2-j — what the next token's conv needs)."""
    k = len(lags)
    return jnp.stack([lags[k - 2 - j] for j in range(k - 1)], axis=2)


def _scatter_state(state, values, slots, is_end):
    """Write per-token values [B,S,...] into state rows [n_slots+1, ...] at
    span-end tokens; every non-end token collapses onto the trailing trash
    row (index -1).  At most one span per slot per call (the engine packs
    one span per sequence per row), so real rows see at most one write."""
    idx = jnp.where(is_end, slots, -1).reshape(-1)
    flat = values.reshape((-1,) + values.shape[2:])
    return state.at[idx].set(flat.astype(state.dtype))


def _rglru_scan(x, r, i, lam):
    """x,r,i: [B,S,D] f32. Returns h [B,S,D] via associative scan."""
    log_a = -_C * jax.nn.softplus(lam) * r          # log a_t  (a_t ∈ (0,1))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_seq, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h


def _rglru_step(x, r, i, lam, h_prev):
    log_a = -_C * jax.nn.softplus(lam) * r
    a = jnp.exp(log_a)
    h = a * h_prev + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)
    return h


def _rglru_scan_packed(x, r, i, lam, h_init, is_start, inject):
    """Multi-span associative scan: like :func:`_rglru_scan` but the
    recurrence resets at span starts and continuation spans resume from
    ``h_init`` [B,S,D] (their slot's stored state, gathered per token).
    Zeroing ``a_t`` at span starts makes one flat scan respect every span
    boundary; adding ``a_t·h_init`` into the injected start's source term
    reproduces the sequential step ``h = a·h_prev + gated`` exactly there."""
    log_a = -_C * jax.nn.softplus(lam) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)
    gated = gated + jnp.where(inject, 1.0, 0.0)[..., None] * a * h_init
    a_eff = a * jnp.where(is_start, 0.0, 1.0)[..., None]

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a_eff, gated), axis=1)
    return h


def apply_rglru(p, x, ctx: layers.Ctx, cfg, *, cache=None, positions=None,
                paged=None):
    """x: [B, S, d]. cache (decode): {'h': [B,Dr] f32, 'conv': [B,3,Dr]}.

    paged (serving): switches to the per-slot state protocol — cache rows
    are [n_slots+1, ...] (trailing trash row).  Prefill routes spans via
    paged["state_slots"]/["state_local"] (packed multi-span scan with
    zero-or-carried initial state, span-end states scattered back); decode
    updates rows [:B], gated on paged["kv_len"] > 0 so masked/inactive
    slots keep their state untouched."""
    b, s, d = x.shape
    xr = x @ p["wx"]                                  # recurrence branch
    xr = ctx.c(xr, "batch", "seq", "rnn")
    gate = jax.nn.gelu(x @ p["wg"])                   # gate branch
    gate = ctx.c(gate, "batch", "seq", "rnn")

    if paged is not None:
        assert cache is not None, "paged serving always threads state rows"
        if ctx.decode:
            xr, new_conv = _conv1d_causal(xr, p["conv"], cache["conv"][:b])
            xf = xr.astype(jnp.float32)
            r = jax.nn.sigmoid(xf @ p["w_rec"].astype(jnp.float32))
            i = jax.nn.sigmoid(xf @ p["w_inp"].astype(jnp.float32))
            h = _rglru_step(xf[:, 0], r[:, 0], i[:, 0], p["lambda"],
                            cache["h"][:b])
            live = (paged["kv_len"] > 0)[:, None]
            new_cache = {
                "h": cache["h"].at[:b].set(
                    jnp.where(live, h, cache["h"][:b])),
                "conv": cache["conv"].at[:b].set(jnp.where(
                    live[:, None], new_conv.astype(cache["conv"].dtype),
                    cache["conv"][:b]))}
            h = h[:, None, :]
        else:
            if "state_slots" not in paged:
                raise ValueError(
                    "recurrent paged prefill needs state routing — pass "
                    "state_slots/state_local (lm.paged_prefill/"
                    "paged_chunk_prefill)")
            slots, local, is_start, inject, is_end = _packed_seg(
                paged, positions)
            xr, lags = _conv1d_causal_packed(xr, p["conv"], cache["conv"],
                                             slots, local,
                                             jnp.broadcast_to(positions,
                                                              slots.shape))
            xf = xr.astype(jnp.float32)
            r = jax.nn.sigmoid(xf @ p["w_rec"].astype(jnp.float32))
            i = jax.nn.sigmoid(xf @ p["w_inp"].astype(jnp.float32))
            h = _rglru_scan_packed(xf, r, i, p["lambda"],
                                   cache["h"][slots].astype(jnp.float32),
                                   is_start, inject)
            new_cache = {
                "h": _scatter_state(cache["h"], h, slots, is_end),
                "conv": _scatter_state(cache["conv"], _conv_state_of(lags),
                                       slots, is_end)}
        h = ctx.c(h.astype(x.dtype), "batch", "seq", "rnn")
        out = (h * gate) @ p["wo"]
        return ctx.c(out, "batch", "seq", "embed"), new_cache

    conv_state = cache["conv"] if cache is not None else None
    xr, new_conv = _conv1d_causal(xr, p["conv"], conv_state)

    xf = xr.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_rec"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_inp"].astype(jnp.float32))

    new_cache = None
    if ctx.decode:
        assert s == 1 and cache is not None
        h = _rglru_step(xf[:, 0], r[:, 0], i[:, 0], p["lambda"], cache["h"])
        new_cache = {"h": h, "conv": new_conv}
        h = h[:, None, :]
    else:
        h = _rglru_scan(xf, r, i, p["lambda"])
        if cache is not None:  # prefill: persist final state
            new_cache = {"h": h[:, -1], "conv": new_conv}
    h = ctx.c(h.astype(x.dtype), "batch", "seq", "rnn")
    out = (h * gate) @ p["wo"]
    return ctx.c(out, "batch", "seq", "embed"), new_cache


def init_rglru_cache(cfg, batch):
    dr = cfg.rglru.d_rnn
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, 3, dr), jnp.float32)}
