"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrence  h_t = a_t ⊙ h_{t-1} + √(1-a_t²) ⊙ (i_t ⊙ x_t)  is a linear
(elementwise, gated) scan — SparkAttention is inapplicable here (no QKᵀ /
softmax), so this mixer is pure JAX (docs/architecture.md). Training
uses an associative scan over the sequence; decode is a single state update.

Block layout (Griffin recurrent block):
  x → [linear → conv1d(4) → RG-LRU]  ⊙  [linear → gelu]  → linear out
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

_C = 8.0  # Griffin's fixed exponent scale for a_t


def init_rglru(key, cfg, dtype):
    d, dr = cfg.d_model, cfg.rglru.d_rnn
    ks = jax.random.split(key, 7)
    p, s = {}, {}
    p["wx"], s["wx"] = layers.dense_init(ks[0], d, dr, dtype, "embed", "rnn")
    p["wg"], s["wg"] = layers.dense_init(ks[1], d, dr, dtype, "embed", "rnn")
    p["wo"], s["wo"] = layers.dense_init(ks[2], dr, d, dtype, "rnn", "embed")
    # conv1d over time, kernel 4, per-channel (depthwise)
    p["conv"] = (jax.random.normal(ks[3], (4, dr), jnp.float32) * 0.1).astype(dtype)
    s["conv"] = (None, "rnn")
    # gates
    p["w_inp"], s["w_inp"] = layers.dense_init(ks[4], dr, dr, dtype, "rnn", "rnn")
    p["w_rec"], s["w_rec"] = layers.dense_init(ks[5], dr, dr, dtype, "rnn", "rnn")
    # Λ init so the retention a_t = exp(−c·r·softplus(Λ)) hits a ∈ (0.9,0.999)
    # at r=1 (Griffin's a_t = a^{c·r} with a = exp(−softplus(Λ)); softplus(Λ)
    # must equal −log(a)/c, so Λ = softplus⁻¹(−log a / c)).
    lam = jax.random.uniform(ks[6], (dr,), jnp.float32, 0.9, 0.999)
    target = -jnp.log(lam) / _C
    p["lambda"] = jnp.log(jnp.expm1(target))      # inverse softplus
    s["lambda"] = ("rnn",)
    return p, s


def _conv1d_causal(x, w, state=None):
    """Depthwise causal conv over time. x [B,S,D], w [K,D].

    state (decode): [B, K-1, D] previous inputs; returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):]
    return y, new_state


def _rglru_scan(x, r, i, lam):
    """x,r,i: [B,S,D] f32. Returns h [B,S,D] via associative scan."""
    log_a = -_C * jax.nn.softplus(lam) * r          # log a_t  (a_t ∈ (0,1))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_seq, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h


def _rglru_step(x, r, i, lam, h_prev):
    log_a = -_C * jax.nn.softplus(lam) * r
    a = jnp.exp(log_a)
    h = a * h_prev + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)
    return h


def apply_rglru(p, x, ctx: layers.Ctx, cfg, *, cache=None):
    """x: [B, S, d]. cache (decode): {'h': [B,Dr] f32, 'conv': [B,3,Dr]}."""
    b, s, d = x.shape
    xr = x @ p["wx"]                                  # recurrence branch
    xr = ctx.c(xr, "batch", "seq", "rnn")
    gate = jax.nn.gelu(x @ p["wg"])                   # gate branch
    gate = ctx.c(gate, "batch", "seq", "rnn")

    conv_state = cache["conv"] if cache is not None else None
    xr, new_conv = _conv1d_causal(xr, p["conv"], conv_state)

    xf = xr.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_rec"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_inp"].astype(jnp.float32))

    new_cache = None
    if ctx.decode:
        assert s == 1 and cache is not None
        h = _rglru_step(xf[:, 0], r[:, 0], i[:, 0], p["lambda"], cache["h"])
        new_cache = {"h": h, "conv": new_conv}
        h = h[:, None, :]
    else:
        h = _rglru_scan(xf, r, i, p["lambda"])
        if cache is not None:  # prefill: persist final state
            new_cache = {"h": h[:, -1], "conv": new_conv}
    h = ctx.c(h.astype(x.dtype), "batch", "seq", "rnn")
    out = (h * gate) @ p["wo"]
    return ctx.c(out, "batch", "seq", "embed"), new_cache


def init_rglru_cache(cfg, batch):
    dr = cfg.rglru.d_rnn
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, 3, dr), jnp.float32)}
