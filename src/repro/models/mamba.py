"""Mamba-1 selective-state-space mixer (falcon-mamba-7b, arXiv:2410.05355).

Attention-free: SparkAttention is inapplicable (no QKᵀ/softmax to fuse);
the arch is supported by the framework with this pure-JAX mixer. The selective
scan h_t = Ā_t ⊙ h_{t-1} + B̄_t x_t is linear in h → associative scan over the
sequence for train/prefill, single-step update for decode.

State per layer: h [B, d_inner, N] (N = ssm_state = 16) + conv tail.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def init_mamba(key, cfg, dtype):
    mc = cfg.mamba
    d, di, n, dt_rank = cfg.d_model, mc.d_inner, mc.ssm_state, mc.dt_rank
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["in_proj"], s["in_proj"] = layers.dense_init(ks[0], d, 2 * di, dtype,
                                                   "embed", "rnn")
    p["conv"] = (jax.random.normal(ks[1], (mc.conv_kernel, di), jnp.float32)
                 * 0.1).astype(dtype)
    s["conv"] = (None, "rnn")
    p["w_bc"], s["w_bc"] = layers.dense_init(ks[2], di, 2 * n, dtype,
                                             "rnn", "state")
    p["w_dt1"], s["w_dt1"] = layers.dense_init(ks[3], di, dt_rank, dtype,
                                               "rnn", None)
    p["w_dt2"], s["w_dt2"] = layers.dense_init(ks[4], dt_rank, di, dtype,
                                               None, "rnn")
    p["dt_bias"] = jnp.zeros((di,), jnp.float32)
    s["dt_bias"] = ("rnn",)
    # A init: -[1..N] broadcast per channel (S4D-real init)
    p["A_log"] = jnp.broadcast_to(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)),
                                  (di, n)).copy()
    s["A_log"] = ("rnn", "state")
    p["D"] = jnp.ones((di,), jnp.float32)
    s["D"] = ("rnn",)
    p["out_proj"], s["out_proj"] = layers.dense_init(ks[5], di, d, dtype,
                                                     "rnn", "embed")
    return p, s


def _ssm_scan(u, dt, B, C, A, D, *, chunk: int = 256):
    """u,dt: [B,S,Di]; B,C: [B,S,N]; A: [Di,N]; D: [Di] → y [B,S,Di] (f32).

    Chunked: a flat associative scan would materialise the [B,S,Di,N] f32
    discretised operands (34 GB/layer for falcon-mamba at 4k×16 local batch —
    caught by the dry-run memory pass). Instead we scan sequentially over
    S/chunk chunks carrying only h [B,Di,N], with an associative scan *inside*
    each chunk — the TPU-friendly shape a fused Mamba kernel would use, with
    peak memory [B,chunk,Di,N].
    """
    bsz, s, di = u.shape
    n = A.shape[1]
    if s % chunk != 0:
        chunk = s
    n_chunks = s // chunk

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    def chunk_body(h_prev, inputs):
        u_c, dt_c, b_c, c_c = inputs                 # [B,chunk,...]
        a_bar = jnp.exp(dt_c[..., None] * A)         # [B,chunk,Di,N]
        bx = (dt_c * u_c)[..., None] * b_c[:, :, None, :]
        a_cum, h_in = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
        # fold in the carried state: h_t = a_{1..t}·h_prev + h_in
        h = h_in + a_cum * h_prev[:, None]
        y_c = jnp.einsum("bsdn,bsn->bsd", h, c_c)
        return h[:, -1], y_c

    split = lambda x: x.reshape(bsz, n_chunks, chunk, *x.shape[2:]
                                ).transpose(1, 0, 2, *range(3, x.ndim + 1))
    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    h_last, yc = jax.lax.scan(chunk_body, h0,
                              (split(u), split(dt), split(B), split(C)))
    y = yc.transpose(1, 0, 2, 3).reshape(bsz, s, di)
    return y + D * u, h_last


def _ssm_step(u, dt, B, C, A, D, h_prev):
    """Single decode step. u,dt: [B,Di]; B,C: [B,N]; h_prev [B,Di,N]."""
    a_bar = jnp.exp(dt[..., None] * A)
    h = a_bar * h_prev + (dt * u)[..., None] * B[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C) + D * u
    return y, h


def _ssm_scan_packed(u, dt, B, C, A, D, state_h, slots, is_start, inject,
                     is_end, *, chunk: int = 256):
    """Multi-span chunked selective scan (packed paged prefill).

    Like :func:`_ssm_scan`, but the recurrence resets at span starts
    (``a_bar`` zeroed there, so the in-chunk associative scan and the
    cross-chunk ``h_prev`` carry both respect span boundaries), continuation
    spans resume from their slot's row of ``state_h`` [n_slots+1, Di, N]
    (``inject`` adds ``a_bar·h_init`` into the start's source term — the
    sequential step's exact arithmetic), and each span-end h scatters back
    to its slot's row inside the chunk scan (non-ends collapse onto the
    trailing trash row).  Returns (y [B,S,Di] f32, new state_h)."""
    bsz, s, di = u.shape
    n = A.shape[1]
    if s % chunk != 0:
        chunk = s
    n_chunks = s // chunk

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    def chunk_body(carry, inputs):
        h_prev, st = carry
        u_c, dt_c, b_c, c_c, sl_c, start_c, inj_c, end_c = inputs
        a_bar = jnp.exp(dt_c[..., None] * A)         # [B,chunk,Di,N]
        bx = (dt_c * u_c)[..., None] * b_c[:, :, None, :]
        h_init = st[sl_c]                            # [B,chunk,Di,N]
        bx = bx + jnp.where(inj_c, 1.0, 0.0)[..., None, None] * a_bar * h_init
        a_eff = a_bar * jnp.where(start_c, 0.0, 1.0)[..., None, None]
        a_cum, h_in = jax.lax.associative_scan(combine, (a_eff, bx), axis=1)
        h = h_in + a_cum * h_prev[:, None]
        y_c = jnp.einsum("bsdn,bsn->bsd", h, c_c)
        idx = jnp.where(end_c, sl_c, -1).reshape(-1)
        st = st.at[idx].set(h.reshape(-1, di, n))
        return (h[:, -1], st), y_c

    split = lambda x: x.reshape(bsz, n_chunks, chunk, *x.shape[2:]
                                ).transpose(1, 0, 2, *range(3, x.ndim + 1))
    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    (_, st), yc = jax.lax.scan(
        chunk_body, (h0, state_h),
        (split(u), split(dt), split(B), split(C),
         split(slots), split(is_start), split(inject), split(is_end)))
    y = yc.transpose(1, 0, 2, 3).reshape(bsz, s, di)
    return y + D * u, st


def apply_mamba(p, x, ctx: layers.Ctx, cfg, *, cache=None, positions=None,
                paged=None):
    """x: [B,S,d]. cache (decode): {'h': [B,Di,N] f32, 'conv': [B,K-1,Di]}.

    paged (serving): per-slot state protocol — cache rows are
    [n_slots+1, ...] (trailing trash row); prefill spans route through
    paged["state_slots"]/["state_local"], decode updates rows [:B] gated
    on paged["kv_len"] > 0 (see rglru.apply_rglru)."""
    from repro.models.rglru import (_conv1d_causal, _conv1d_causal_packed,
                                    _conv_state_of, _packed_seg,
                                    _scatter_state)
    b, s, d = x.shape
    h_in = x @ p["in_proj"]
    h_in = ctx.c(h_in, "batch", "seq", "rnn")
    u, z = jnp.split(h_in, 2, axis=-1)

    packed = paged is not None and not ctx.decode
    if packed:
        if "state_slots" not in paged:
            raise ValueError(
                "recurrent paged prefill needs state routing — pass "
                "state_slots/state_local (lm.paged_prefill/"
                "paged_chunk_prefill)")
        slots, local, is_start, inject, is_end = _packed_seg(paged, positions)
        u, lags = _conv1d_causal_packed(u, p["conv"], cache["conv"], slots,
                                        local,
                                        jnp.broadcast_to(positions,
                                                         slots.shape))
        new_conv = None
    else:
        conv_state = (cache["conv"][:b] if paged is not None
                      else cache["conv"] if cache is not None else None)
        u, new_conv = _conv1d_causal(u, p["conv"], conv_state)
    u = jax.nn.silu(u).astype(jnp.float32)

    bc = (u.astype(x.dtype) @ p["w_bc"]).astype(jnp.float32)
    Bm, Cm = jnp.split(bc, 2, axis=-1)                       # [B,S,N] each
    dt = jax.nn.softplus(
        (u.astype(x.dtype) @ p["w_dt1"] @ p["w_dt2"]).astype(jnp.float32)
        + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    new_cache = None
    if ctx.decode:
        assert s == 1 and cache is not None
        h_prev = cache["h"][:b] if paged is not None else cache["h"]
        y, h_new = _ssm_step(u[:, 0], dt[:, 0], Bm[:, 0], Cm[:, 0], A, p["D"],
                             h_prev)
        if paged is not None:
            live = (paged["kv_len"] > 0)[:, None]
            new_cache = {
                "h": cache["h"].at[:b].set(
                    jnp.where(live[..., None], h_new, h_prev)),
                "conv": cache["conv"].at[:b].set(jnp.where(
                    live[:, None], new_conv.astype(cache["conv"].dtype),
                    cache["conv"][:b]))}
        else:
            new_cache = {"h": h_new, "conv": new_conv}
        y = y[:, None, :]
    elif packed:
        y, new_h = _ssm_scan_packed(u, dt, Bm, Cm, A, p["D"], cache["h"],
                                    slots, is_start, inject, is_end)
        new_cache = {
            "h": new_h,
            "conv": _scatter_state(cache["conv"], _conv_state_of(lags),
                                   slots, is_end)}
    else:
        y, h_last = _ssm_scan(u, dt, Bm, Cm, A, p["D"])
        if cache is not None:
            new_cache = {"h": h_last, "conv": new_conv}
    y = ctx.c(y.astype(x.dtype), "batch", "seq", "rnn")
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return ctx.c(out, "batch", "seq", "embed"), new_cache


def init_mamba_cache(cfg, batch):
    mc = cfg.mamba
    return {"h": jnp.zeros((batch, mc.d_inner, mc.ssm_state), jnp.float32),
            "conv": jnp.zeros((batch, mc.conv_kernel - 1, mc.d_inner),
                              jnp.float32)}
