"""Shared model building blocks (pure JAX, param pytrees + logical-axis specs).

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the params
pytree with tuples of *logical axis names* per dim. The sharding-rule engine
(distributed/sharding.py) maps logical names → mesh axes per architecture.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.attention import (spark_attention, spark_decode,
                                  spark_paged_decode)
from repro.core.online_softmax import NEG_INF


# ---------------------------------------------------------------------------
# context threaded through every apply function
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Ctx:
    """Per-call context: mesh/sharding hooks + mode flags + dropout seed."""
    constrain: Any = None            # fn(x, logical_axes) -> x (or None)
    impl: str = "xla"                # attention impl
    deterministic: bool = True       # disables dropout
    seed: Any = 0                    # traced dropout seed
    decode: bool = False             # single-token decode step
    xla_chunk: int = 1024
    xla_unroll: bool = False         # unroll attention chunk scans (cost pass)
    decode_write: str = "dus"        # KV write: "dus" | "onehot" (see below)
    block_q: int = 128
    block_kv: int = 128
    num_splits: int = 1              # split-KV decode grid cells per (B,Hkv)
                                     # row (kernels/decode.py; chosen by
                                     # perf/autotune.py when serving opts in)
    acc_dtype: Any = jnp.float32
    bwd_acc_dtype: Any = jnp.float32
    mesh: Any = None                 # set by the paged serving steps when the
                                     # page pool is sharded: attention routes
                                     # its pool scatter/decode through the
                                     # shard_map paths in distributed/paged.py

    def c(self, x, *axes):
        """Apply an activation sharding constraint if a mesh is attached."""
        if self.constrain is None:
            return x
        return self.constrain(x, axes)


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype, in_axis="embed", out_axis="mlp",
               scale=None):
    scale = (d_in ** -0.5) if scale is None else scale
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    return w, (in_axis, out_axis)


def norm_init(dim, dtype):
    return jnp.ones((dim,), dtype), ("embed",)


# ---------------------------------------------------------------------------
# primitive ops
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def rope(x, positions, *, base: float = 10000.0):
    """Rotary embedding. x: [B, S, H, D] (D even), positions: [B, S] or [S]."""
    b, s, h, d = x.shape
    half = d // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]          # [B, S, 1, half]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softmax_cross_entropy(logits, labels, vocab_size: int, weights=None):
    """Mean CE over positions. logits [B,S,V] (V may be padded), labels [B,S].

    weights: optional [B,S] per-position mask/weights — weighted mean over
    positions with weight > 0 (packed batches mask segment boundaries)."""
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab_size:  # mask vocab padding
        neg = jnp.full((logits.shape[-1] - vocab_size,), NEG_INF, jnp.float32)
        logits = logits.at[..., vocab_size:].set(neg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    if weights is None:
        return jnp.mean(ce)
    w = weights.astype(jnp.float32)
    return jnp.sum(ce * w) / jnp.maximum(jnp.sum(w), 1.0)


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype, gated: bool = True):
    k1, k2 = jax.random.split(key)
    width = 2 * d_ff if gated else d_ff
    wi, si = dense_init(k1, d_model, width, dtype, "embed", "mlp")
    wo, so = dense_init(k2, d_ff, d_model, dtype, "mlp", "embed")
    return {"wi": wi, "wo": wo}, {"wi": si, "wo": so}


def apply_mlp(p, x, ctx: Ctx, *, gated: bool = True):
    h = x @ p["wi"]
    h = ctx.c(h, "batch", "seq", "mlp")
    if gated:
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(h)
    out = h @ p["wo"]
    return ctx.c(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Attention block (the paper's technique lives here)
# ---------------------------------------------------------------------------

def paged_decode_window(cfg) -> Optional[int]:
    """The sliding window the *paged* decode path masks with (None = full
    attention).

    Single source of truth shared by the kernel calls below and the serving
    engine's out-of-window page reclamation: the engine may free exactly the
    pages whose every position this mask excludes, so the two must agree or
    reclamation would free pages the kernel still reads.  (The contiguous
    decode path instead keeps a ``window``-slot ring buffer and needs no
    mask — see the decode branch in :func:`apply_attention`.)
    """
    return cfg.attn_window


def init_attention(key, cfg, dtype):
    """cfg: ArchConfig-like with num_heads/num_kv_heads/head_dim/d_model/qk_norm."""
    ks = jax.random.split(key, 4)
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], d, hq * hd, dtype, "embed", "q_proj")
    p["wk"], s["wk"] = dense_init(ks[1], d, hkv * hd, dtype, "embed", "kv_proj")
    p["wv"], s["wv"] = dense_init(ks[2], d, hkv * hd, dtype, "embed", "kv_proj")
    p["wo"], s["wo"] = dense_init(ks[3], hq * hd, d, dtype, "q_proj", "embed")
    if cfg.qk_norm:
        p["q_norm"], s["q_norm"] = jnp.ones((hd,), dtype), ("head_dim",)
        p["k_norm"], s["k_norm"] = jnp.ones((hd,), dtype), ("head_dim",)
    return p, s


def apply_attention(p, x, ctx: Ctx, cfg, *, positions=None, cache=None,
                    layer_seed=0, segment_ids=None, paged=None):
    """x: [B, S, d]. Returns (out, new_cache).

    cache (decode/prefill): dict with k/v [B, Hkv, S_max, D] and index scalar,
    OR a *paged* cache dict with k_pages/v_pages [Hkv, num_pages, page_size, D]
    (a global page pool — see serving/paged_cache.py).
    segment_ids [B, S]: packed-batch segment ids — attention stays within a
    segment (pair with per-segment ``positions`` so RoPE restarts at each
    packed sequence). Training path, and packed *prefill* onto a paged cache.
    paged: serving-side routing for paged caches —
      prefill: {"dest": [B, S]} flat page-pool token slots per input token
      (padding → the trash page), precomputed by BlockTables.prefill_dest;
      decode: {"block_tables": [B, T], "kv_len": [B]};
      chunked/suffix prefill additionally carries {"token_tables": [B, S, T],
      "token_kv_len": [B, S]} — each token then attends through its own
      block-table row (history pages + same-row predecessors) instead of the
      in-row segment mask; positions are global per token.
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)

    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, positions)
    k = rope(k, positions)

    q = ctx.c(q.transpose(0, 2, 1, 3), "batch", "heads", "seq_full", "head_dim")
    k = ctx.c(k.transpose(0, 2, 1, 3), "batch", "kv_heads", "seq_full", "head_dim")
    v = ctx.c(v.transpose(0, 2, 1, 3), "batch", "kv_heads", "seq_full", "head_dim")

    new_cache = None
    if ctx.decode:
        # Append one token, then flash-decode over the cache. Sliding-window
        # archs use the cache as a RING buffer of `window` slots: RoPE bakes
        # absolute positions into K at write time and softmax is permutation-
        # invariant over keys, so slot order inside the ring is irrelevant and
        # no window mask is needed (every resident entry is in-window).
        assert s == 1 and cache is not None
        # the cache carries no segment structure, so a segment mask cannot be
        # honored here — packed prompts separate at prefill (paged path) and
        # decode as independent batch rows
        assert segment_ids is None, \
            "segment_ids apply to training and packed prefill; decode rows " \
            "are independent sequences"
        if "k_pages" in cache:
            # paged decode: append this token's K/V into its sequence's
            # current page (block_tables/kv_len name the slot), then
            # flash-decode with the block-table gather. Inactive slots point
            # at the trash page and carry kv_len == 0 — their writes and
            # logits are garbage by construction and ignored by the engine.
            assert paged is not None, "paged cache needs block_tables/kv_len"
            bt, kvl = paged["block_tables"], paged["kv_len"]
            if ctx.mesh is not None:
                # distributed pool (page dim sharded over the model axis):
                # per-shard local scatter + local attention, merged with the
                # online-softmax partial merge — see distributed/paged.py
                from repro.distributed.paged import paged_append_decode_sharded
                o, ck, cv = paged_append_decode_sharded(
                    q[:, :, 0, :], k[:, :, 0, :], v[:, :, 0, :],
                    cache["k_pages"], cache["v_pages"], bt, kvl,
                    mesh=ctx.mesh, impl=ctx.impl,
                    window=paged_decode_window(cfg),
                    num_splits=ctx.num_splits)
                o = o[:, :, None, :]
            else:
                ps = cache["k_pages"].shape[2]
                page = jnp.take_along_axis(bt, (kvl // ps)[:, None],
                                           axis=1)[:, 0]
                dest = page * ps + kvl % ps                   # [B] token slots
                ck = _scatter_pages(cache["k_pages"], dest,
                                    k[:, :, 0, :].transpose(1, 0, 2))
                cv = _scatter_pages(cache["v_pages"], dest,
                                    v[:, :, 0, :].transpose(1, 0, 2))
                # no ring buffer here — sliding windows mask inside the
                # kernel, and the engine frees fully-masked-out pages early
                # (their table entries revert to the trash page, which this
                # same window gate skips without reading)
                o = spark_paged_decode(q[:, :, 0, :], ck, cv, bt, kvl + 1,
                                       impl=ctx.impl,
                                       window=paged_decode_window(cfg),
                                       num_splits=ctx.num_splits
                                       )[:, :, None, :]
            new_cache = {"k_pages": ck, "v_pages": cv}
            o = ctx.c(o, "batch", "heads", "seq_full", "head_dim")
            out = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd) @ p["wo"]
            return ctx.c(out, "batch", "seq", "embed"), new_cache
        idx = cache["index"]
        cap = cache["k"].shape[2]
        slot = idx % cap if cfg.attn_window is not None else idx
        if ctx.decode_write == "onehot":
            # Elementwise ring write: dynamic_update_slice at a traced index
            # on a sharded seq dim forces GSPMD into "involuntary full
            # rematerialization" (replicate + repartition the whole cache per
            # token — caught by the v0 dry-run). A one-hot select is
            # elementwise on the sharded dim → stays local on every shard.
            hot = (jnp.arange(cap, dtype=jnp.int32) == slot)[None, None, :, None]
            ck = jnp.where(hot, k.astype(cache["k"].dtype), cache["k"])
            cv = jnp.where(hot, v.astype(cache["v"].dtype), cache["v"])
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))
        ck = ctx.c(ck, "batch", "kv_heads", "kv_cache_seq", "head_dim")
        cv = ctx.c(cv, "batch", "kv_heads", "kv_cache_seq", "head_dim")
        kv_len = jnp.full((b,), jnp.minimum(idx + 1, cap), jnp.int32)
        o = spark_decode(q[:, :, 0, :], ck, cv, impl=ctx.impl, kv_len=kv_len,
                         window=None, block_kv=ctx.block_kv,
                         num_splits=ctx.num_splits)
        o = o[:, :, None, :]
        new_cache = {"k": ck, "v": cv, "index": idx + 1}
    else:
        if cache is not None and "k_pages" in cache:
            # segment-aware PACKED prefill: many prompts share one fused
            # forward row; the PR-1 segment mask keeps their attention
            # disjoint, and each token's K/V scatters into its own
            # sequence's pages via the precomputed dest slots (padding
            # tokens land in the trash page). One kernel launch fills every
            # admitted prompt's cache — no per-prompt padding traffic.
            assert paged is not None and "dest" in paged, \
                "packed prefill onto a paged cache needs dest token slots"
            dest = paged["dest"].reshape(-1)                  # [B*S]
            kv_vals = (k.transpose(1, 0, 2, 3).reshape(hkv, b * s, hd),
                       v.transpose(1, 0, 2, 3).reshape(hkv, b * s, hd))
            if ctx.mesh is not None:
                # sharded pool: each shard keeps the writes that land in its
                # pages; foreign tokens hit its local trash page
                from repro.distributed.paged import scatter_pages_sharded
                ck = scatter_pages_sharded(cache["k_pages"], dest, kv_vals[0],
                                           mesh=ctx.mesh)
                cv = scatter_pages_sharded(cache["v_pages"], dest, kv_vals[1],
                                           mesh=ctx.mesh)
            else:
                ck = _scatter_pages(cache["k_pages"], dest, kv_vals[0])
                cv = _scatter_pages(cache["v_pages"], dest, kv_vals[1])
            new_cache = {"k_pages": ck, "v_pages": cv}
            if "token_tables" in paged:
                # CHUNKED / suffix prefill: these tokens continue sequences
                # whose earlier tokens already live in pages (prefix-cache
                # hits, earlier chunks), so in-row attention is not enough.
                # The scatter above ran first, so each token can attend to
                # *everything* before it — history pages and same-row
                # predecessors alike — through one per-token block-table
                # read: token t becomes its own decode row with its slot's
                # table and kv_len = position + 1 (0 for padding → the
                # kv_len gate finalizes those rows to exact zeros).  No new
                # kernel: this is the split-KV paged decode with B·S rows.
                bt_tok = paged["token_tables"].reshape(b * s, -1)
                kvl_tok = paged["token_kv_len"].reshape(b * s)
                q_tok = q.transpose(0, 2, 1, 3).reshape(b * s, hq, hd)
                if ctx.mesh is not None:
                    from repro.distributed.paged import paged_decode_sharded
                    o_tok = paged_decode_sharded(
                        q_tok, ck, cv, bt_tok, kvl_tok, mesh=ctx.mesh,
                        impl=ctx.impl, window=paged_decode_window(cfg),
                        num_splits=ctx.num_splits)
                else:
                    o_tok = spark_paged_decode(
                        q_tok, ck, cv, bt_tok, kvl_tok, impl=ctx.impl,
                        window=paged_decode_window(cfg),
                        num_splits=ctx.num_splits)
                o = o_tok.reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
                o = ctx.c(o, "batch", "heads", "seq_full", "head_dim")
                out = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd) @ p["wo"]
                return ctx.c(out, "batch", "seq", "embed"), new_cache
        elif cache is not None:  # contiguous prefill (position 0): fill it
            # this cache stores no segment structure, so a packed prefill
            # would silently decode across prompt boundaries later — packed
            # prefill requires the paged cache above
            assert segment_ids is None, \
                "packed prefill needs a paged cache (make_serve_steps paged=)"
            cap = cache["k"].shape[2]
            kc = k.astype(cache["k"].dtype)
            vc = v.astype(cache["v"].dtype)
            if s >= cap:  # windowed ring: keep the last `cap` tokens, by-slot
                shift = (s - cap) % cap
                kc = jnp.roll(kc[:, :, s - cap:], shift, axis=2)
                vc = jnp.roll(vc[:, :, s - cap:], shift, axis=2)
                ck, cv = kc, vc
            else:
                ck = jax.lax.dynamic_update_slice(cache["k"], kc, (0, 0, 0, 0))
                cv = jax.lax.dynamic_update_slice(cache["v"], vc, (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv, "index": cache["index"] + s}
        drop = 0.0 if ctx.deterministic else cfg.dropout_rate
        o = spark_attention(q, k, v, impl=ctx.impl, seed=ctx.seed + layer_seed,
                            causal=cfg.causal, window=cfg.attn_window,
                            dropout_rate=drop, segment_ids=segment_ids,
                            acc_dtype=ctx.acc_dtype,
                            bwd_acc_dtype=ctx.bwd_acc_dtype,
                            block_q=ctx.block_q, block_kv=ctx.block_kv,
                            xla_chunk=ctx.xla_chunk, xla_unroll=ctx.xla_unroll)

    o = ctx.c(o, "batch", "heads", "seq_full", "head_dim")
    out = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd) @ p["wo"]
    return ctx.c(out, "batch", "seq", "embed"), new_cache


def init_attn_cache(cfg, batch, max_len, dtype):
    shape = (batch, cfg.num_kv_heads, max_len, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "index": jnp.int32(0)}


def _scatter_pages(pages, dest, vals):
    """Write token rows into the page pool at flat token slots.

    pages [Hkv, num_pages, page_size, D]; dest [N] int32 flat slots
    (page * page_size + offset; duplicates only ever target the trash page);
    vals [Hkv, N, D].
    """
    hkv, n_pages, ps, d = pages.shape
    flat = pages.reshape(hkv, n_pages * ps, d)
    return flat.at[:, dest].set(vals.astype(pages.dtype)).reshape(pages.shape)


def init_paged_attn_cache(cfg, paged_cfg, dtype):
    """One attention layer's page pool (shared by all sequences; page 0 is
    the trash page — see serving/paged_cache.py)."""
    shape = (cfg.num_kv_heads, paged_cfg.num_pages, paged_cfg.page_size,
             cfg.head_dim)
    return {"k_pages": jnp.zeros(shape, dtype),
            "v_pages": jnp.zeros(shape, dtype)}
