"""Online-softmax state algebra (paper Eq. 2/3, after FA/FA2).

The state of a partially-computed softmax-weighted sum over a row is the triple
``(m, l, acc)``:

    m   : running row max of the scores seen so far            (f32)
    l   : running sum of exp(score - m)                        (f32)
    acc : running sum of exp(score - m) @ V                    (f32)

Two states over disjoint score blocks merge associatively (paper Eq. 3):

    m   = max(m1, m2)
    l   = e^{m1-m} l1 + e^{m2-m} l2
    acc = e^{m1-m} acc1 + e^{m2-m} acc2

and the finished row is ``acc / l`` with log-sum-exp ``lse = m + log l``.

These tiny functions are the single source of truth used by:
  * the Pallas kernels (per kv-block update),
  * the pure-XLA chunked fallback (lax.scan carry),
  * the distributed flash-decode merge (cross-device partial combine),
  * the split-KV decode finalize (``merge_many`` over the splits axis),
  * the hypothesis property tests (associativity / shift invariance).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps exp() NaN-free on all paths


class SoftmaxState(NamedTuple):
    m: jnp.ndarray    # [..., rows]         running max
    l: jnp.ndarray    # [..., rows]         running denominator
    acc: jnp.ndarray  # [..., rows, d]      running numerator @ V


def init_state(rows_shape, d: int, dtype=jnp.float32) -> SoftmaxState:
    return SoftmaxState(
        m=jnp.full(rows_shape, NEG_INF, dtype),
        l=jnp.zeros(rows_shape, dtype),
        acc=jnp.zeros((*rows_shape, d), dtype),
    )


def update(state: SoftmaxState, s: jnp.ndarray, v: jnp.ndarray) -> SoftmaxState:
    """Fold one block of scores ``s [..., rows, cols]`` and values ``v [..., cols, d]``."""
    m_blk = jnp.max(s, axis=-1)
    m_new = jnp.maximum(state.m, m_blk)
    alpha = jnp.exp(state.m - m_new)                       # rescale of old state
    # rows whose scores are all masked keep m == NEG_INF; exp(s - m) would be
    # exp(0) = 1 there. Shift by 0 instead so p == 0, l stays 0, and finalize's
    # l == 0 guard emits zeros (fully-masked rows, e.g. packed-batch padding).
    m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
    p = jnp.exp(s - m_safe[..., None])                     # unnormalised probs
    l_new = state.l * alpha + jnp.sum(p, axis=-1)
    acc_new = state.acc * alpha[..., None] + p @ v.astype(p.dtype)
    return SoftmaxState(m_new, l_new, acc_new)


def merge(s1: SoftmaxState, s2: SoftmaxState) -> SoftmaxState:
    """Associative merge of two disjoint-block states (paper Eq. 3)."""
    m = jnp.maximum(s1.m, s2.m)
    a1 = jnp.exp(s1.m - m)
    a2 = jnp.exp(s2.m - m)
    return SoftmaxState(
        m=m,
        l=s1.l * a1 + s2.l * a2,
        acc=s1.acc * a1[..., None] + s2.acc * a2[..., None],
    )


def merge_many(state: SoftmaxState, axis: int = 0) -> SoftmaxState:
    """Vectorized merge of N disjoint-block states stacked along ``axis``.

    The N-way form of :func:`merge` in one shot (one max + one exp-rescaled
    sum over the stacked axis) — used to combine split-KV decode partials.
    Because :func:`merge` is associative and commutative (the property tests
    fuzz it), this equals any pairwise merge order. ``axis`` indexes ``m``/
    ``l``; ``acc`` carries one extra trailing feature dim. All-empty stacks
    (every ``m == NEG_INF``) come out as the empty state, NaN-free, because
    NEG_INF is a large *finite* negative.
    """
    if axis < 0:
        axis += state.m.ndim
    m = jnp.max(state.m, axis=axis)
    a = jnp.exp(state.m - jnp.expand_dims(m, axis))
    return SoftmaxState(
        m=m,
        l=jnp.sum(state.l * a, axis=axis),
        acc=jnp.sum(state.acc * a[..., None], axis=axis),
    )


def finalize(state: SoftmaxState, out_dtype=None):
    """Return (o, lse). Rows that saw only masked scores produce zeros."""
    l_safe = jnp.where(state.l == 0.0, 1.0, state.l)
    o = state.acc / l_safe[..., None]
    lse = state.m + jnp.log(l_safe)
    if out_dtype is not None:
        o = o.astype(out_dtype)
    return o, lse
