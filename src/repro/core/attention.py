"""SparkAttention public API — the paper's contribution as a composable module.

One entry point, three interchangeable execution paths:

* ``impl="pallas"``            — the fused Pallas TPU kernels (production path).
* ``impl="pallas_interpret"``  — same kernels, interpret mode (CPU validation).
* ``impl="xla"``               — the identical online-softmax algorithm as a
                                 chunked ``lax.scan`` in plain XLA; O(N) memory.
                                 Used by the CPU dry-run so lowered HLO matches
                                 the kernel algorithm's memory profile.
* ``impl="naive"``             — the unfused baseline (paper's PyTorch/cuBLAS
                                 comparison point). O(N²) memory.

All paths are numerically interchangeable (tests assert it) and differentiable.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ops import AttnConfig

IMPLS = ("pallas", "pallas_interpret", "xla", "naive")


def spark_attention(q, k, v, *, impl: str = "xla", seed=0,
                    causal: bool = False, window: Optional[int] = None,
                    scale: Optional[float] = None, dropout_rate: float = 0.0,
                    segment_ids=None,
                    acc_dtype=jnp.float32, bwd_acc_dtype=jnp.float32,
                    block_q: int = 128, block_kv: int = 128,
                    xla_chunk: int = 1024, xla_unroll: bool = False):
    """Fused MHA. q [B,Hq,Sq,D], k/v [B,Hkv,Skv,D] → [B,Hq,Sq,D].

    segment_ids: optional [B, Skv] int32 per-token segment ids for packed
    (variable-length) batches — attention never crosses a segment boundary,
    negative ids mark padding tokens that attend to nothing. Supported by all
    four impls with identical semantics (tests assert interchangeability).
    """
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    cfg = AttnConfig(causal=causal, window=window, scale=scale,
                     dropout_rate=dropout_rate, acc_dtype=acc_dtype,
                     bwd_acc_dtype=bwd_acc_dtype, block_q=block_q,
                     block_kv=block_kv, interpret=(impl == "pallas_interpret"))
    if impl in ("pallas", "pallas_interpret"):
        return ops.mha(q, k, v, seed=seed, segment_ids=segment_ids, config=cfg)
    if impl == "xla":
        return ops.mha_xla(q, k, v, seed=seed, segment_ids=segment_ids,
                           config=cfg, chunk=xla_chunk, unroll=xla_unroll)
    return ops.mha_reference(q, k, v, seed=seed, segment_ids=segment_ids,
                             config=cfg)


def spark_decode(q, k, v, *, impl: str = "xla", kv_len=None,
                 window: Optional[int] = None, scale: Optional[float] = None,
                 block_kv: int = 512, num_splits: int = 1):
    """Single-token decode against a KV cache. q [B,Hq,D] → [B,Hq,D].

    num_splits > 1 runs the split-KV scheme on every impl: the KV axis is
    partitioned into that many slices whose un-normalised (acc, m, l) states
    merge in f32 (``online_softmax.merge_many``) — more parallel work at
    serving shapes for one tiny merge pass. ``perf/autotune.py`` picks the
    value; all impls stay numerically interchangeable (tests assert it).
    """
    if impl in ("pallas", "pallas_interpret"):
        return ops.decode(q, k, v, kv_len=kv_len, window=window, scale=scale,
                          block_kv=block_kv, num_splits=num_splits,
                          interpret=(impl == "pallas_interpret"))
    # XLA path: a single query row — the score vector is [B,H,S] (same order of
    # memory as one KV head slice), so the direct masked form is already I/O
    # optimal for decode. Splits mirror the kernel's partial-state algebra.
    if num_splits > 1:
        acc, m, l = _xla_split_decode_partials(q, k, v, kv_len=kv_len,
                                               window=window, scale=scale,
                                               num_splits=num_splits)
        from repro.core import online_softmax as osm
        o, _ = osm.finalize(osm.SoftmaxState(m=m, l=l, acc=acc),
                            out_dtype=q.dtype)
        return o
    return _xla_masked_decode(q, k, v, kv_len=kv_len, window=window, scale=scale)


def spark_paged_decode(q, k_pages, v_pages, block_tables, kv_len, *,
                       impl: str = "xla", window: Optional[int] = None,
                       scale: Optional[float] = None, num_splits: int = 1):
    """Single-token decode against a paged KV cache (serving subsystem).

    q [B,Hq,D]; k_pages/v_pages [Hkv,num_pages,page_size,D] global page pool;
    block_tables [B,T] int32 physical page per logical KV block (entries past a
    row's allocation must hold valid ids — the pool's trash page 0); kv_len [B].

    The Pallas path scalar-prefetches each row's block table and gathers its
    pages HBM→VMEM inside the kernel pipeline; the XLA path materialises the
    gather (jnp fancy-index) and reuses the contiguous masked decode — same
    numerics, used by the CPU dry-run and as the serving fallback.
    ``num_splits``: split-KV over the table width (see :func:`spark_decode`).
    """
    if impl in ("pallas", "pallas_interpret"):
        return ops.paged_decode(q, k_pages, v_pages, block_tables, kv_len,
                                window=window, scale=scale,
                                num_splits=num_splits,
                                interpret=(impl == "pallas_interpret"))
    return spark_decode(q, ops.gather_pages(k_pages, block_tables),
                        ops.gather_pages(v_pages, block_tables),
                        impl="xla", kv_len=kv_len, window=window, scale=scale,
                        num_splits=num_splits)


def spark_paged_decode_partials(q, k_pages, v_pages, block_tables, kv_len, *,
                                block_valid=None, impl: str = "xla",
                                window: Optional[int] = None,
                                scale: Optional[float] = None,
                                num_splits: int = 1):
    """Paged decode returning the un-finalized online-softmax state.

    The building block of *distributed* paged serving: each shard of a
    page-sharded pool calls this with its local pages, a block table remapped
    to local ids, and ``block_valid [B, T]`` marking the entries it owns
    (invalid entries point at the local trash page and contribute nothing).
    Returns f32 ``(acc [B,Hq,D], m [B,Hq], l [B,Hq])``; merge shards with the
    ``online_softmax`` algebra and finalize once (see distributed/paged.py).
    ``num_splits > 1`` computes the shard-local state as a merge of split-KV
    partials — identical output, so it composes with the cross-shard merge.
    """
    if impl in ("pallas", "pallas_interpret"):
        return ops.paged_decode_partials(
            q, k_pages, v_pages, block_tables, kv_len,
            block_valid=block_valid, window=window, scale=scale,
            num_splits=num_splits, interpret=(impl == "pallas_interpret"))
    ps = k_pages.shape[2]
    pos_valid = None
    if block_valid is not None:
        pos_valid = jnp.repeat(block_valid.astype(bool), ps, axis=1)
    if num_splits > 1:
        return _xla_split_decode_partials(
            q, ops.gather_pages(k_pages, block_tables),
            ops.gather_pages(v_pages, block_tables),
            kv_len=kv_len, window=window, scale=scale, pos_valid=pos_valid,
            num_splits=num_splits)
    return _xla_masked_decode_partials(
        q, ops.gather_pages(k_pages, block_tables),
        ops.gather_pages(v_pages, block_tables),
        kv_len=kv_len, window=window, scale=scale, pos_valid=pos_valid)


def _xla_masked_decode(q, k, v, *, kv_len=None, window=None, scale=None):
    from repro.core import online_softmax as osm
    acc, m, l = _xla_masked_decode_partials(q, k, v, kv_len=kv_len,
                                            window=window, scale=scale)
    o, _ = osm.finalize(osm.SoftmaxState(m=m, l=l, acc=acc),
                        out_dtype=q.dtype)
    return o


def _xla_masked_decode_partials(q, k, v, *, kv_len=None, window=None,
                                scale=None, pos_valid=None, kv_start=0):
    """Masked single-query decode, stopping at the un-normalised
    ``online_softmax`` state (acc, m, l) over the positions this caller is
    allowed to see (``pos_valid [B, Skv]`` gates shard-local ownership).
    ``kv_start`` offsets the slice's global positions — a split-KV chunk
    passes its slice of K/V plus its offset and gets the partial state over
    exactly its positions (``kv_len``/``window`` stay global).
    Fully-masked rows keep ``m == NEG_INF, l == 0, acc == 0`` so they merge
    and finalize to exact zeros, matching the kernels' convention.
    ``_xla_masked_decode`` is this plus ``online_softmax.finalize``."""
    from repro.core.online_softmax import NEG_INF
    from repro.kernels.ref import _expand_kv
    b, hq, d = q.shape
    skv = k.shape[2]
    scale = (d ** -0.5) if scale is None else scale
    kf = _expand_kv(k, hq)
    vf = _expand_kv(v, hq)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    kp = kv_start + jnp.arange(skv)[None, None, :]
    if kv_len is None:
        kv_len = jnp.full((b,), kv_start + skv, jnp.int32)
    L = kv_len[:, None, None]
    allowed = kp < L
    if window is not None:
        allowed &= kp > (L - 1) - window
    if pos_valid is not None:
        allowed &= pos_valid[:, None, :]
    s = jnp.where(allowed, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(m == NEG_INF, 0.0, m)   # exp(NEG_INF - NEG_INF) == 1
    # sparklint: disable=no-inline-softmax-fold -- single-block partial state built in one shot with an explicit where(allowed); guard is m_safe above
    p = jnp.where(allowed, jnp.exp(s - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhk,bhkd->bhd", p, vf.astype(jnp.float32))
    return acc, m, l


def _xla_split_decode_partials(q, k, v, *, kv_len=None, window=None,
                               scale=None, pos_valid=None, num_splits=2):
    """Split-KV decode in plain XLA: the kernel's scheme, mirrored.

    The KV axis is cut into ``num_splits`` contiguous slices; each slice's
    un-normalised state comes from :func:`_xla_masked_decode_partials` with
    its global ``kv_start`` offset, and the stacked states merge with the
    vectorized ``online_softmax.merge_many`` — the same algebra the Pallas
    split kernels use, so the dry-run's lowered HLO matches the kernel
    algorithm's parallelism structure.  Returns the merged (acc, m, l).
    """
    from repro.core import online_softmax as osm
    b = q.shape[0]
    skv = k.shape[2]
    num_splits = max(1, min(num_splits, skv))
    chunk = -(-skv // num_splits)
    if kv_len is None:
        kv_len = jnp.full((b,), skv, jnp.int32)
    parts = []
    for i in range(num_splits):
        lo, hi = i * chunk, min((i + 1) * chunk, skv)
        if lo >= hi:
            continue
        pv = None if pos_valid is None else pos_valid[:, lo:hi]
        acc, m, l = _xla_masked_decode_partials(
            q, k[:, :, lo:hi], v[:, :, lo:hi], kv_len=kv_len, window=window,
            scale=scale, pos_valid=pv, kv_start=lo)
        parts.append(osm.SoftmaxState(m=m, l=l, acc=acc))
    state = osm.merge_many(
        osm.SoftmaxState(m=jnp.stack([p.m for p in parts]),
                         l=jnp.stack([p.l for p in parts]),
                         acc=jnp.stack([p.acc for p in parts])), axis=0)
    return state.acc, state.m, state.l
