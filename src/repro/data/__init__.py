from repro.data.synthetic import DataConfig, batch_iterator, make_batch
from repro.data.loader import PrefetchLoader

__all__ = ["DataConfig", "batch_iterator", "make_batch", "PrefetchLoader"]
