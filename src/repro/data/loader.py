"""Sharded, prefetching device loader.

Places each host batch directly into its device-sharded layout (no full-batch
replication through host memory on any single device) and prefetches the next
batch on a background thread while the current step runs — compute/IO overlap,
the data-pipeline half of the paper's "keep the TCUs busy" argument.

Batches are plain dicts; packed (varlen) batches simply carry two extra keys
('segment_ids', 'positions') that flow through placement untouched — missing
sharding entries fall back to default device placement.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax


class PrefetchLoader:
    def __init__(self, host_iter: Iterator[dict], shardings: Optional[dict],
                 prefetch: int = 2):
        self._it = host_iter
        self._shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._err = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch: dict):
        if self._shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {k: jax.device_put(v, self._shardings.get(k)) for k, v in
                batch.items()}

    def _worker(self):
        try:
            for batch in self._it:
                self._q.put(self._place(batch))
        except Exception as e:  # surfaced on next __next__
            self._err = e
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err:
                raise self._err
            raise StopIteration
        return item
