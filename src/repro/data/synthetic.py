"""Synthetic data pipeline (the paper evaluates on random data, §4.1).

Deterministic, restart-safe: batch contents are a pure function of
(seed, step), so a resumed run consumes the identical stream — required for
the checkpoint/restart determinism tests and for elastic re-sharding.

The token stream is not uniform noise: it is a Zipf-ish mixture with a
copy-structure so the LM loss actually decreases during the example runs.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend: Optional[str] = None   # None → token LM; vision/audio → embeds
    frontend_dim: int = 1024
    # sequence packing (varlen training): each row packs several short
    # documents back-to-back; batches gain 'segment_ids' (per-token document
    # id, non-decreasing along the row) and 'positions' (restarting per doc).
    pack: bool = False
    min_seg_len: int = 16
    max_seg_len: int = 64


def _zipf_tokens(rs: np.random.RandomState, shape, vocab):
    """Zipf-distributed ids with local copy structure (learnable signal)."""
    ranks = rs.zipf(1.3, size=shape).astype(np.int64)
    toks = (ranks - 1) % vocab
    # copy-structure: with p=0.3, token t+1 repeats token t (bigram signal)
    rep = rs.rand(*shape) < 0.3
    toks_shift = np.roll(toks, 1, axis=-1)
    toks = np.where(rep, toks_shift, toks)
    return toks.astype(np.int32)


def _pack_layout(rs: np.random.RandomState, batch: int, seq_len: int,
                 min_len: int, max_len: int):
    """Deterministic per-row packing: segment ids (0,1,2,… non-decreasing) and
    per-segment positions. Rows are filled exactly (final doc truncated), so
    there is no padding; downstream padding uses negative segment ids."""
    assert 1 <= min_len <= max_len, (
        f"packing needs 1 <= min_seg_len <= max_seg_len, "
        f"got {min_len}..{max_len}")
    seg_ids = np.zeros((batch, seq_len), np.int32)
    positions = np.zeros((batch, seq_len), np.int32)
    for i in range(batch):
        t, sid = 0, 0
        while t < seq_len:
            n = min(int(rs.randint(min_len, max_len + 1)), seq_len - t)
            seg_ids[i, t:t + n] = sid
            positions[i, t:t + n] = np.arange(n)
            t += n
            sid += 1
    return seg_ids, positions


def make_batch(cfg: DataConfig, step: int):
    """Pure function of (cfg.seed, step) → host numpy batch."""
    rs = np.random.RandomState((cfg.seed * 1_000_003 + step) % (2**31 - 1))
    shape = (cfg.global_batch, cfg.seq_len)
    labels = _zipf_tokens(rs, shape, cfg.vocab_size)
    if cfg.frontend is None:
        batch = {"tokens": labels, "labels": labels}
        if cfg.pack:
            seg_ids, positions = _pack_layout(
                rs, cfg.global_batch, cfg.seq_len,
                cfg.min_seg_len, cfg.max_seg_len)
            batch["segment_ids"] = seg_ids
            batch["positions"] = positions
        return batch
    assert not cfg.pack, "sequence packing is token-LM only (no frontends)"
    embeds = rs.randn(cfg.global_batch, cfg.seq_len,
                      cfg.frontend_dim).astype(np.float32)
    return {"embeds": embeds, "labels": labels}


def batch_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield make_batch(cfg, step)
        step += 1
