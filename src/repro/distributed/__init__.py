from repro.distributed.sharding import (ShardingRules, default_rules,
                                        vocab_pad_for)

__all__ = ["ShardingRules", "default_rules", "vocab_pad_for"]
