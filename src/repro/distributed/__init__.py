from repro.distributed.sharding import (ShardingRules, default_rules,
                                        vocab_pad_for)


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` (the repo supports jax 0.4.x → 0.6+).

    Replication/VMA checking is always off: the distributed attention paths
    wrap ``pallas_call``, whose out_shapes carry no varying-mesh-axes info, so
    the checker rejects them spuriously on every jax version that has it.
    """
    import jax
    if hasattr(jax, "shard_map"):                  # jax >= 0.6
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


__all__ = ["ShardingRules", "default_rules", "vocab_pad_for", "shard_map"]
