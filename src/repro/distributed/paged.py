"""Distributed paged-KV serving: page-aligned pool sharding + sharded decode.

The serving page pool (``[Hkv, num_pages, page_size, D]`` per attention
layer — see serving/paged_cache.py) distributes over the mesh's **model**
axis by sharding the ``num_pages`` dim: sharding is at page granularity, so
pages never straddle shards, and block tables keep *global* page ids — the
host-side allocator/scheduler are unchanged.

Two invariants make the distribution correct:

* **Page alignment** — ``num_pages`` must divide by the shard count
  (:func:`pages_per_shard` validates); shard ``s`` owns global pages
  ``[s·P, (s+1)·P)`` where ``P = num_pages // n_shards``.
* **A trash page per shard** — global page ``s·P`` (local page 0 of shard
  ``s``) is reserved: every shard remaps table entries it does not own to its
  local page 0, and scatter writes for tokens it does not own land there, so
  every local table entry and every local write stays a valid local page.
  ``PagedCacheConfig(num_shards=n)`` keeps the allocator away from these ids;
  global page 0 remains THE trash page for host-side bookkeeping.

Decode runs as per-shard local attention + online-softmax partial merge
(exactly the seq-sharded contiguous-decode rule in sharding.py, applied to
pages): each shard computes the un-normalised ``(acc, m, l)`` state over its
own pages (``spark_paged_decode_partials``), then tiny ``[B,H]`` /
``[B,H,D]`` all-reduces merge the states — never the pool. Without the
partial merge, GSPMD would all-gather every sequence's whole cache per token.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.attention import spark_paged_decode_partials

POOL_AXIS = "model"  # mesh axis the page dim shards over (TP axis)


def pool_shard_count(mesh: Optional[Mesh], axis: str = POOL_AXIS) -> int:
    if mesh is None:
        return 1
    return int(mesh.shape.get(axis, 1))


def pages_per_shard(num_pages: int, n_shards: int) -> int:
    """Pages owned by each shard; validates the page-aligned-split invariant."""
    if num_pages % n_shards != 0:
        raise ValueError(
            f"num_pages={num_pages} must divide by the pool shard count "
            f"{n_shards}: sharding is at page granularity (pages never "
            f"straddle shards)")
    per = num_pages // n_shards
    if per < 2:
        raise ValueError(
            f"{per} page(s) per shard leaves no usable page beside the "
            f"per-shard trash page — grow num_pages or shrink the mesh")
    return per


def pool_sharding(mesh: Mesh, axis: str = POOL_AXIS) -> NamedSharding:
    """NamedSharding for one [Hkv, num_pages, page_size, D] page pool."""
    return NamedSharding(mesh, P(None, axis, None, None))


def _local_ids(bt, n_local: int, shard):
    """Global table → (local table, ownership mask) for one pool shard."""
    owner = bt // n_local
    local = owner == shard
    # non-local entries → local trash page 0 (a valid local id by invariant)
    return jnp.where(local, bt % n_local, 0), local.astype(jnp.int32)


def _scatter_local(pages, dest, vals, n_local_slots: int, shard):
    """Shard-local flat-slot scatter: tokens owned elsewhere hit local trash.

    pages [Hkv, P_local, ps, D] (this shard's slice); dest [N] *global* flat
    token slots (page·page_size + offset); vals [Hkv, N, D].
    """
    hkv, p_local, ps, d = pages.shape
    owner = dest // n_local_slots
    local_dest = jnp.where(owner == shard, dest % n_local_slots, 0)
    flat = pages.reshape(hkv, p_local * ps, d)
    return flat.at[:, local_dest].set(vals.astype(pages.dtype)).reshape(
        pages.shape)


def merge_partials(acc, m, l, axis_name: str, out_dtype=None):
    """Cross-shard online-softmax merge + finalize (paper Eq. 3 over shards).

    acc [B,H,D], m/l [B,H] — each shard's local state. The collective form of
    ``online_softmax.merge`` (pmax for the max, the exp-rescaled sums as
    psums), finalized by ``online_softmax.finalize`` so rows with no valid
    positions anywhere (inactive decode slots) come out as exact zeros. The
    collectives move O(B·H·D) bytes per layer per token. NEG_INF is a large
    *finite* negative, so the exp rescale stays NaN-free on empty shards.
    """
    from repro.core import online_softmax as osm
    m_g = jax.lax.pmax(m, axis_name)
    a = jnp.exp(m - m_g)          # empty shards: a→0 (or l==0 makes it inert)
    state = osm.SoftmaxState(
        m=m_g,
        l=jax.lax.psum(l * a, axis_name),
        acc=jax.lax.psum(acc * a[..., None], axis_name))
    o, _ = osm.finalize(state, out_dtype=out_dtype)
    return o


def scatter_pages_sharded(pages, dest, vals, *, mesh: Mesh,
                          axis: str = POOL_AXIS):
    """Sharded counterpart of layers._scatter_pages (packed-prefill writes).

    pages [Hkv, num_pages, ps, D] (page dim sharded over ``axis``); dest [N]
    global flat token slots; vals [Hkv, N, D] (replicated). Each shard keeps
    only the writes that land in its pages; the rest go to its trash page.
    """
    from repro.distributed import shard_map
    n_shards = pool_shard_count(mesh, axis)
    n_local_slots = (pages.shape[1] // n_shards) * pages.shape[2]

    def local(pages_l, dest_l, vals_l):
        shard = jax.lax.axis_index(axis)
        return _scatter_local(pages_l, dest_l, vals_l, n_local_slots, shard)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(None, axis), P(), P()),
                     out_specs=P(None, axis))(pages, dest, vals)


def paged_decode_sharded(q, k_pages, v_pages, block_tables, kv_len, *,
                         mesh: Mesh, axis: str = POOL_AXIS, impl: str = "xla",
                         window: Optional[int] = None,
                         scale: Optional[float] = None, num_splits: int = 1):
    """Sharded paged decode, no append: the distributed counterpart of
    ``spark_paged_decode`` (q replicated, pool page-sharded over ``axis``,
    global block tables). Benchmark/tooling entry point — the serving step
    uses :func:`paged_append_decode_sharded`, which also writes the new
    token's K/V. ``num_splits`` applies split-KV *within* each shard: the
    shard-local splits merge locally, then the cross-shard merge below — the
    same associative algebra at two nesting levels."""
    from repro.distributed import shard_map
    n_local = pages_per_shard(k_pages.shape[1], pool_shard_count(mesh, axis))

    def local(q_l, kp, vp, bt, kvl):
        shard = jax.lax.axis_index(axis)
        bt_local, valid = _local_ids(bt, n_local, shard)
        acc, m, l = spark_paged_decode_partials(
            q_l, kp, vp, bt_local, kvl, block_valid=valid, impl=impl,
            window=window, scale=scale, num_splits=num_splits)
        return merge_partials(acc, m, l, axis, out_dtype=q_l.dtype)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(), P(None, axis), P(None, axis), P(), P()),
                     out_specs=P())(q, k_pages, v_pages,
                                    block_tables.astype(jnp.int32),
                                    kv_len.astype(jnp.int32))


def paged_append_decode_sharded(q, k_new, v_new, k_pages, v_pages,
                                block_tables, kv_len, *, mesh: Mesh,
                                axis: str = POOL_AXIS, impl: str = "xla",
                                window: Optional[int] = None,
                                scale: Optional[float] = None,
                                num_splits: int = 1):
    """One sharded paged-decode step: append this token's K/V, then attend.

    q/k_new/v_new [B, H(kv), D] (replicated activations — the decode rules
    replicate q and gather the per-token projection rows, see sharding.py);
    k_pages/v_pages [Hkv, num_pages, ps, D] sharded on the page dim over
    ``axis``; block_tables [B, T] global ids; kv_len [B] pre-append lengths.

    Returns (o [B, Hq, D], new_k_pages, new_v_pages) — o replicated, pools
    still sharded. Inside: per-shard local scatter + local partial attention
    (optionally split-KV within the shard via ``num_splits`` — shard-local
    splits merge locally, then cross-shard), merged with tiny all-reduces
    (module docstring).
    """
    from repro.distributed import shard_map
    n_shards = pool_shard_count(mesh, axis)
    ps = k_pages.shape[2]
    n_local = pages_per_shard(k_pages.shape[1], n_shards)

    def local(q_l, kn, vn, kp, vp, bt, kvl):
        shard = jax.lax.axis_index(axis)
        page = jnp.take_along_axis(bt, (kvl // ps)[:, None], axis=1)[:, 0]
        dest = page * ps + kvl % ps                      # [B] global slots
        kp = _scatter_local(kp, dest, kn.transpose(1, 0, 2), n_local * ps,
                            shard)
        vp = _scatter_local(vp, dest, vn.transpose(1, 0, 2), n_local * ps,
                            shard)
        bt_local, valid = _local_ids(bt, n_local, shard)
        acc, m, l = spark_paged_decode_partials(
            q_l, kp, vp, bt_local, kvl + 1, block_valid=valid, impl=impl,
            window=window, scale=scale, num_splits=num_splits)
        o = merge_partials(acc, m, l, axis, out_dtype=q_l.dtype)
        return o, kp, vp

    return shard_map(local, mesh=mesh,
                     in_specs=(P(), P(), P(), P(None, axis), P(None, axis),
                               P(), P()),
                     out_specs=(P(), P(None, axis), P(None, axis)))(
        q, k_new, v_new, k_pages, v_pages,
        block_tables.astype(jnp.int32), kv_len.astype(jnp.int32))
