"""Logical-axis sharding rules with divisibility fallback.

Params and activations are annotated with *logical* axis names
(models/layers.py); this module maps them onto mesh axes per architecture and
records every fallback it takes, so the dry-run can report exactly how each of
the 10 heterogeneous archs was laid out on the same (pod, data, model) mesh.

Key rules (see docs/architecture.md):
  batch        → (pod, data)  [DP]
  seq          → model        [Megatron-style sequence parallelism between
                               layers; attention/MLP gather internally]
  heads/mlp/vocab/experts/rnn → model  [TP/EP], iff divisible, else replicate
  embed (param dim) → data when cfg.fsdp  [FSDP/ZeRO; gathered per layer]

The full ZeRO-3 profile (``_fsdp_rules``: no TP at all, params and batch
jointly over (data, model)) replaces the rule set above only when the config
*opts in* to parameter sharding with ``fsdp=True`` AND selects
``sharding_profile="fsdp"``.  The profile string alone is an annotation of
what the hillclimb found best at production scale; honoring it without the
``fsdp`` opt-in silently FSDP-shards the embed/vocab axis where TP /
replication is expected, which turns per-layer weight gathers into
whole-table all-gathers (the seed-state bug behind the four
``test_sharding_rules`` xfails and the sharded-vs-single-device drift in
``test_distributed``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    rules: Dict[str, Any]                 # logical name → mesh axis (or None)
    fallbacks: Dict[str, str] = dataclasses.field(default_factory=dict)

    def spec_for(self, logical_axes: Tuple[Optional[str], ...],
                 shape: Optional[Tuple[int, ...]] = None) -> P:
        """Map logical axes → PartitionSpec, dropping non-divisible entries."""
        parts = []
        for i, name in enumerate(logical_axes):
            axis = self.rules.get(name) if name else None
            if axis is None:
                parts.append(None)
                continue
            size = _axis_size(self.mesh, axis)
            if shape is not None and shape[i] % size != 0:
                self.fallbacks[f"{name}[{shape[i]}]"] = (
                    f"not divisible by {axis}={size} → replicated")
                parts.append(None)
            else:
                parts.append(axis)
        # a mesh axis may appear at most once in a spec
        seen = set()
        clean = []
        for p_ in parts:
            names = p_ if isinstance(p_, tuple) else (p_,)
            if p_ is not None and any(n in seen for n in names):
                clean.append(None)
            else:
                clean.append(p_)
                seen.update(n for n in names if n)
        return P(*clean)

    def sharding_for(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, shape))

    def constrain(self, x, logical_axes):
        """Activation sharding constraint (used as Ctx.constrain)."""
        spec = self.spec_for(tuple(logical_axes), tuple(x.shape))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def tree_shardings(self, params, specs):
        """NamedSharding pytree for a param pytree + logical-spec pytree."""
        return jax.tree.map(
            lambda p, s: self.sharding_for(tuple(s), tuple(p.shape)),
            params, specs, is_leaf=lambda x: isinstance(x, tuple))


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
        return size
    return mesh.shape[axis]


def default_rules(mesh: Mesh, cfg, *, serve: bool = False,
                  decode: bool = False) -> ShardingRules:
    """Per-arch logical→mesh mapping.

    The same rule set covers train and serve: non-divisible dims (e.g. a
    decode step's seq=1, or kv_heads=8 on a 16-way model axis) fall back to
    replication automatically, and the spec builder never assigns one mesh
    axis twice — so e.g. the KV cache shards over kv_heads when divisible and
    over cache sequence (distributed flash-decode) otherwise."""
    dp: Any = tuple(a for a in ("pod", "data") if a in mesh.shape) or None
    if dp is not None and len(dp) == 1:
        dp = dp[0]
    tp = "model" if "model" in mesh.shape else None

    if not serve and uses_fsdp_profile(cfg):
        # ZeRO-3 needs BOTH flags: the profile string alone is a scale
        # annotation, not an opt-in (module docstring) — without cfg.fsdp the
        # arch keeps the TP-SP rules below.
        return _fsdp_rules(mesh, cfg)  # train-only profile (see above)

    rules: Dict[str, Any] = {
        # activations
        "batch": dp,
        "seq": tp,            # sequence-parallel residuals between layers
        "seq_full": None,     # inside attention: per-device full seq
        "heads": tp,
        "kv_heads": tp,
        "head_dim": None,
        "moe_groups": dp,
        # params
        "vocab": tp,
        "embed": None,
        "mlp": tp,
        "expert_mlp": None,   # per-expert FFN dim stays local (E is sharded)
        "q_proj": tp,
        "kv_proj": tp,
        "experts": tp,
        "rnn": tp,
        "state": None,
        "layers": None,       # stacked-scan leading dim
        "kv_cache_seq": tp,   # long-KV decode: cache seq sharded when kv_heads
                              # can't be (spec builder enforces axis uniqueness)
    }
    if (cfg is not None and getattr(cfg, "fsdp", False) and not decode
            and "data" in mesh.shape):
        # FSDP: weights gathered per layer inside scan. Train + prefill only
        # (both have whole-sequence compute to overlap the gathers); per-token
        # weight all-gathers would dominate decode (qwen3 decode went
        # 6ms→146ms when FSDP leaked into decode rules — §Perf iteration 3).
        rules["embed"] = "data"
    if decode and tp is not None and cfg is not None and cfg.num_kv_heads \
            and cfg.num_kv_heads % _axis_size(mesh, tp) != 0:
        # Distributed flash-decode: the cache is seq-sharded (kv_heads can't
        # shard). If q stayed heads-sharded, GSPMD must all-gather the WHOLE
        # cache every token (190 GB/token for deepseek-67b — §Perf iteration
        # 3). Replicating the q *activation* instead (weights stay sharded)
        # lets GSPMD emit the online-softmax partial merge: per-shard local
        # attention + tiny [b,h]/[b,h,d] all-reduces.
        rules["heads"] = None
        # Projection WEIGHTS shard on the fused (heads·head_dim) dim — always
        # divisible even when the head count isn't. The resulting activation
        # gather is one [B,1,H·D] row per token (KBs); without this, decode
        # replicated q/k/v/o projections (+24 GB/dev on llava — §Perf it. 3).
        rules["q_proj"] = tp
        rules["kv_proj"] = tp
    tp_size = _axis_size(mesh, tp) if tp else 1
    if cfg is not None and cfg.num_heads:
        if cfg.num_heads % tp_size != 0:
            # heads not divisible (qwen3 40, llava 56, rg 10 on tp=16):
            # replicate head-projections; activations fall back automatically.
            rules["heads"] = None
            rules["q_proj"] = None
            if getattr(cfg, "ctx_parallel_attn", False):
                # context parallelism: shard attention QUERY rows over the
                # model axis instead — each shard computes all heads for its
                # sequence slice (full KV), removing the tp_size× replication
                # of attention compute (perf hillclimb iteration 4).
                rules["seq_full"] = tp
        if cfg.num_kv_heads % tp_size != 0:
            rules["kv_heads"] = None
            rules["kv_proj"] = None
    return ShardingRules(mesh=mesh, rules=rules)


def _fsdp_rules(mesh: Mesh, cfg) -> ShardingRules:
    """FSDP/ZeRO-3 profile: no tensor parallelism. Batch shards over
    (data, model) jointly; every param's *embed* dim shards over the same
    axes (weights all-gathered per layer, grads reduce-scattered). Collective
    bytes scale with weight size instead of activation size — the right
    profile when TP-SP activation traffic dominates (small d_model, or
    large-batch training of dense stacks; see launch/hillclimb.py)."""
    fs: Any = tuple(a for a in ("data", "model") if a in mesh.shape) or None
    if fs is not None and len(fs) == 1:
        fs = fs[0]
    # pod stays pure gradient-replica DP so global_batch=256 still divides.
    rules: Dict[str, Any] = {
        "batch": fs,
        "seq": None, "seq_full": None,
        "heads": None, "kv_heads": None, "head_dim": None,
        "moe_groups": fs,
        "vocab": None, "embed": fs,
        "mlp": None, "expert_mlp": None,
        "q_proj": None, "kv_proj": None,
        "experts": None, "rnn": None, "state": None,
        "layers": None,
        "kv_cache_seq": None,
    }
    return ShardingRules(mesh=mesh, rules=rules)


def uses_fsdp_profile(cfg) -> bool:
    """Does this config take the full ZeRO-3 profile from ``default_rules``?

    Single source of the profile gate, shared with the dry-run / analytic
    memory model so their layout assumptions match what actually compiles:
    BOTH the ``sharding_profile="fsdp"`` annotation and the explicit
    ``fsdp=True`` opt-in are required (module docstring)."""
    return (cfg is not None
            and getattr(cfg, "sharding_profile", "tp_sp") == "fsdp"
            and getattr(cfg, "fsdp", False))


def vocab_pad_for(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)
