"""int8 error-feedback gradient all-reduce (beyond-paper distributed opt).

Data-parallel gradient all-reduce dominates the collective roofline term for
small/medium archs at train_4k. This module quantises each gradient tensor to
int8 with a per-tensor scale before the cross-DP psum and keeps the
quantisation residual in an *error-feedback* buffer added to the next step's
gradient — the standard EF-SGD construction that preserves convergence.

Implementation: grads are computed per-DP-shard inside ``shard_map`` (so no
automatic psum has happened yet), quantised, psum'd as int32 (wire format
int8 — 4× fewer collective bytes; XLA transfers the narrow type), dequantised.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_psum(g: jnp.ndarray, axis_names, error: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One tensor: (grad_shard + error) → int8 psum → (mean_grad, new_error)."""
    gf = g.astype(jnp.float32) + error
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_error = gf - q.astype(jnp.float32) * scale
    # scale must be identical on every shard → psum-max it first
    scale = jax.lax.pmax(scale, axis_names)
    q = jnp.clip(jnp.round((g.astype(jnp.float32) + error) / scale),
                 -127, 127).astype(jnp.int8)
    new_error = g.astype(jnp.float32) + error - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_names)
    # psum of 1 = axis size (jax.lax.axis_size only exists on newer jax)
    n = jax.lax.psum(1, axis_names)
    return total.astype(jnp.float32) * scale / n, new_error


def psum_tree_int8(grads, errors, axis_names):
    """Apply quantize_psum over a gradient pytree. Returns (grads, errors)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        mg, ne = quantize_psum(g, axis_names, e)
        out_g.append(mg)
        out_e.append(ne)
    return (jax.tree.unflatten(treedef, out_g),
            jax.tree.unflatten(treedef, out_e))


def init_error_buffers(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
