from repro.optim.adamw import (AdamWConfig, AdamWState, adamw_init,
                               adamw_update, clip_by_global_norm, global_norm)
from repro.optim.schedule import cosine_schedule, linear_warmup

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "global_norm", "cosine_schedule",
           "linear_warmup"]
