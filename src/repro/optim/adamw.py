"""AdamW with f32 master state over (possibly bf16) params — ZeRO-friendly.

The optimizer state mirrors the param pytree, so whatever sharding the rules
engine assigns to a param automatically applies to its m/v/master slots
(ZeRO-1 falls out of FSDP param sharding for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    keep_master: bool = True  # f32 master copy when params are bf16


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any  # f32 params (or None when keep_master=False)


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # explicit copy: when params are already f32, astype would alias the param
    # buffer and break donation (double-donate) in the jitted step.
    master = (jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                           params)
              if cfg.keep_master else None)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros), master=master)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig, lr=None):
    """Returns (new_params, new_state, metrics). lr may be a traced scalar."""
    lr = cfg.lr if lr is None else lr
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state.m, grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state.v, grads)
    base = state.master if cfg.keep_master else params

    def upd(p, m, v):
        pf = p.astype(jnp.float32)
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps) + cfg.weight_decay * pf
        return pf - lr * u

    new_master = jax.tree.map(upd, base, new_m, new_v)
    new_params = jax.tree.map(
        lambda nm, p: nm.astype(p.dtype), new_master, params)
    new_state = AdamWState(step=step, m=new_m, v=new_v,
                           master=new_master if cfg.keep_master else None)
    return new_params, new_state, {"grad_norm": gnorm}
