"""Learning-rate schedules (pure functions of a traced step)."""

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int, peak: float):
    return peak * jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))


def cosine_schedule(step, warmup_steps: int, total_steps: int, peak: float,
                    floor_frac: float = 0.1):
    warm = linear_warmup(step, warmup_steps, peak)
    frac = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                    0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 *
                  (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup_steps, warm, cos)
