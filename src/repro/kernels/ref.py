"""Pure-jnp oracles for the SparkAttention kernels.

Two references:

* :func:`naive_mha` — the "traditional" unfused computation the paper benchmarks
  against (materialises S and P; 5 HBM reads + 3 writes in the paper's I/O
  accounting). Used as the numerical oracle for every kernel test and as the
  *baseline* implementation in the paper-table benchmarks.

* :func:`online_mha` — the same fused *algorithm* as the Pallas kernel but
  expressed as a chunked ``lax.scan`` in plain XLA ops (O(chunk) memory, online
  softmax). This is what the multi-pod dry-run lowers, so the compiled HLO's
  memory profile matches the kernel's algorithm instead of the naive O(N²) one.

Conventions (shared by every implementation in this repo):
  q: [B, Hq, Sq, D]   k/v: [B, Hkv, Skv, D]   with Hq % Hkv == 0 (GQA)
  q tokens are the *suffix* of the kv sequence: global q position =
  (Skv - Sq) + i. ``causal`` masks kv_pos > q_pos; ``window=w`` additionally
  masks kv_pos <= q_pos - w (sliding-window / local attention).
  ``segment_ids [B, Skv]`` masks cross-segment pairs (packed/varlen batches);
  negative ids are padding — those rows emit zeros and lse == NEG_INF.
Returns (o [B, Hq, Sq, D] in q.dtype, lse [B, Hq, Sq] f32).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.online_softmax import NEG_INF, SoftmaxState, finalize, update
from repro.kernels import rng


def _expand_kv(x: jnp.ndarray, hq: int) -> jnp.ndarray:
    """[B, Hkv, S, D] -> [B, Hq, S, D] by repeating each kv head over its group."""
    b, hkv, s, d = x.shape
    if hkv == hq:
        return x
    assert hq % hkv == 0, f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}"
    return jnp.repeat(x, hq // hkv, axis=1)


def mask_bias(sq: int, skv: int, *, causal: bool, window: Optional[int],
              dtype=jnp.float32) -> Optional[jnp.ndarray]:
    """[Sq, Skv] additive bias (0 where allowed, NEG_INF where masked)."""
    if not causal and window is None:
        return None
    offset = skv - sq
    qp = jnp.arange(sq)[:, None] + offset
    kp = jnp.arange(skv)[None, :]
    allowed = jnp.ones((sq, skv), bool)
    if causal:
        allowed &= kp <= qp
    if window is not None:
        allowed &= kp > qp - window
    return jnp.where(allowed, 0.0, NEG_INF).astype(dtype)


def dropout_mask(seed: int, b_idx, h_idx, sq: int, skv: int, rate: float,
                 q_offset: int = 0) -> jnp.ndarray:
    """Full [Sq, Skv] keep-mask for one (batch, head) — mirrors the in-kernel RNG."""
    qp = (jnp.arange(sq, dtype=jnp.int32) + q_offset)[:, None]
    kp = jnp.arange(skv, dtype=jnp.int32)[None, :]
    return rng.dropout_keep_mask(rate, seed, b_idx, h_idx, qp, kp)


@functools.partial(jax.jit, static_argnames=("causal", "window", "dropout_rate",
                                             "acc_dtype", "return_residuals"))
def naive_mha(q, k, v, *, causal: bool = False, window: Optional[int] = None,
              scale: Optional[float] = None, dropout_rate: float = 0.0,
              dropout_seed: int = 0, segment_ids=None, acc_dtype=jnp.float32,
              return_residuals: bool = False):
    """Unfused attention oracle. All softmax math in f32; matmuls in acc_dtype.

    segment_ids: optional [B, Skv] int32 per-token segment ids (q is the kv
    suffix). Cross-segment scores are masked; negative ids mark padding.
    Fully-masked rows produce o == 0 and lse == NEG_INF (matching the fused
    kernels' l == 0 finalize path), never NaN or a uniform average.
    """
    b, hq, sq, d = q.shape
    scale = (d ** -0.5) if scale is None else scale
    k = _expand_kv(k, hq)
    v = _expand_kv(v, hq)

    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=acc_dtype).astype(jnp.float32) * scale
    bias = mask_bias(sq, k.shape[2], causal=causal, window=window)
    if bias is not None:
        s = s + bias
    if segment_ids is not None:
        seg = jnp.asarray(segment_ids, jnp.int32)
        q_seg = seg[:, k.shape[2] - sq:]
        seg_ok = ((q_seg[:, :, None] == seg[:, None, :]) &
                  (q_seg[:, :, None] >= 0))[:, None]       # [B, 1, Sq, Skv]
        s = jnp.where(seg_ok, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    # fully-masked rows: m == NEG_INF ⇒ exp(s - m) would be 1 everywhere; use
    # a shifted max so p == 0 and the l == 0 guard yields zeros, not averages.
    m_safe = jnp.where(m == NEG_INF, 0.0, m)
    # sparklint: disable=no-inline-softmax-fold -- the naive oracle must stay an independent reimplementation to test the fold against
    p = jnp.exp(s - m_safe)
    l = jnp.sum(p, axis=-1, keepdims=True)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    lse = (m + jnp.log(l_safe))[..., 0]
    p = p / l_safe
    if dropout_rate > 0.0:
        q_offset = k.shape[2] - sq
        bi = jnp.arange(b, dtype=jnp.int32)[:, None, None, None]
        hi = jnp.arange(hq, dtype=jnp.int32)[None, :, None, None]
        qp = (jnp.arange(sq, dtype=jnp.int32) + q_offset)[None, None, :, None]
        kp = jnp.arange(k.shape[2], dtype=jnp.int32)[None, None, None, :]
        keep = rng.dropout_keep_mask(dropout_rate, dropout_seed, bi, hi, qp, kp)
        p = jnp.where(keep, p / (1.0 - dropout_rate), 0.0)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v,
                   preferred_element_type=acc_dtype).astype(q.dtype)
    if return_residuals:
        return o, lse
    return o


def _fold_gqa(q, hkv):
    """[B,Hq,Sq,D] → [B,Hkv,Sq·G,D] with **sq-major** row order: row =
    sq_idx·G + group_idx. K/V are used per kv-head directly (no G× expansion)
    AND a sharding on Sq propagates through the merge (major-component merge
    is GSPMD-representable — the [g,sq] minor-merge ordering forced full
    replication of context-parallel attention, §Perf iteration 4)."""
    b, hq, sq, d = q.shape
    g = hq // hkv
    q = q.reshape(b, hkv, g, sq, d).transpose(0, 1, 3, 2, 4)  # [b,hkv,sq,g,d]
    return q.reshape(b, hkv, sq * g, d), g


def _unfold_gqa(x, hq, sq):
    """[B,Hkv,Sq·G,(D)] → [B,Hq,Sq,(D)], inverse of _fold_gqa."""
    b, hkv = x.shape[:2]
    g = hq // hkv
    tail = x.shape[3:]
    x = x.reshape(b, hkv, sq, g, *tail)
    x = jnp.moveaxis(x, 3, 2)                                 # [b,hkv,g,sq,..]
    return x.reshape(b, hq, sq, *tail)


def _block_masks(b, hkv, g, sq, chunk, ci, *, q_offset, causal, window,
                 dropout_rate, dropout_seed, q_seg_rows=None, seg_blk=None):
    """(additive-mask allowed, dropout keep) for folded-GQA score blocks.
    Row order is sq-major: qp = row // g, group = row % g.
    q_seg_rows [b, rows] / seg_blk [b, chunk]: per-token segment ids (packed
    batches); cross-segment and negative-id (padding) pairs are masked."""
    rows = sq * g
    row = jnp.arange(rows, dtype=jnp.int32)
    qp = (row // g + q_offset)[:, None]                  # [rows, 1]
    kp = (jnp.arange(chunk, dtype=jnp.int32) + ci * chunk)[None, :]
    allowed = None
    if causal:
        allowed = kp <= qp
    if window is not None:
        w_ok = kp > qp - window
        allowed = w_ok if allowed is None else (allowed & w_ok)
    if q_seg_rows is not None:
        seg_ok = ((q_seg_rows[:, :, None] == seg_blk[:, None, :]) &
                  (q_seg_rows[:, :, None] >= 0))[:, None]  # [b, 1, rows, chunk]
        allowed = seg_ok if allowed is None else (allowed & seg_ok)
    keep = None
    if dropout_rate > 0.0:
        bi = jnp.arange(b, dtype=jnp.int32)[:, None, None, None]
        hk = jnp.arange(hkv, dtype=jnp.int32)[None, :, None, None]
        hq_row = (hk * g + (row % g)[None, None, :, None])   # global q head
        keep = rng.dropout_keep_mask(dropout_rate, dropout_seed, bi, hq_row,
                                     qp[None, None], kp[None, None])
    return allowed, keep


def _online_fwd(q, k, v, seed, seg, *, causal, window, scale, dropout_rate,
                acc_dtype, chunk, unroll):
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if skv % chunk != 0:
        chunk = skv
    n_chunks = skv // chunk
    q_offset = skv - sq
    qf, g = _fold_gqa(q.astype(acc_dtype), hkv)

    kc = k.reshape(b, hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    q_seg_rows = segc = None
    if seg is not None:
        seg = jnp.asarray(seg, jnp.int32)
        # [b, sq*g] sq-major rows (matches _fold_gqa ordering)
        q_seg_rows = jnp.repeat(seg[:, q_offset:], g, axis=1)
        segc = seg.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(state: SoftmaxState, inputs):
        if seg is None:
            ci, k_blk, v_blk = inputs
            seg_blk = None
        else:
            ci, k_blk, v_blk, seg_blk = inputs
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, k_blk.astype(acc_dtype),
                       preferred_element_type=acc_dtype
                       ).astype(jnp.float32) * scale
        allowed, keep = _block_masks(b, hkv, g, sq, chunk, ci,
                                     q_offset=q_offset, causal=causal,
                                     window=window, dropout_rate=dropout_rate,
                                     dropout_seed=seed,
                                     q_seg_rows=q_seg_rows, seg_blk=seg_blk)
        if allowed is not None:
            s = jnp.where(allowed, s, NEG_INF)
        m_new = jnp.maximum(state.m, jnp.max(s, axis=-1))
        alpha = jnp.exp(state.m - m_new)
        # fully-masked-so-far rows (m == NEG_INF): exp(s - m) would be 1; shift
        # so p == 0 and finalize's l == 0 guard yields zeros (see flash_fwd).
        m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
        # sparklint: disable=no-inline-softmax-fold -- dropout hooks between the l update and P·V, which online_softmax.update cannot express; guard present
        p = jnp.exp(s - m_safe[..., None])
        l_new = state.l * alpha + jnp.sum(p, axis=-1)
        p_kept = p if keep is None else \
            jnp.where(keep, p / (1.0 - dropout_rate), 0.0)
        acc_new = (state.acc * alpha[..., None]
                   + jnp.einsum("bhqk,bhkd->bhqd", p_kept.astype(acc_dtype),
                                v_blk.astype(acc_dtype),
                                preferred_element_type=acc_dtype
                                ).astype(jnp.float32))
        return SoftmaxState(m_new, l_new, acc_new), None

    rows = g * sq
    init = SoftmaxState(
        m=jnp.full((b, hkv, rows), NEG_INF, jnp.float32),
        l=jnp.zeros((b, hkv, rows), jnp.float32),
        acc=jnp.zeros((b, hkv, rows, d), jnp.float32),
    )
    if unroll:  # dry-run cost pass: scan bodies are undercounted by XLA cost
        state = init
        for ci in range(n_chunks):
            inp = (jnp.int32(ci), kc[ci], vc[ci])
            state, _ = body(state, inp if seg is None else inp + (segc[ci],))
    else:
        xs = (jnp.arange(n_chunks), kc, vc)
        state, _ = jax.lax.scan(body, init,
                                xs if seg is None else xs + (segc,))
    o, lse = finalize(state, out_dtype=q.dtype)
    o = _unfold_gqa(o, hq, sq)
    lse = _unfold_gqa(lse, hq, sq)
    return o, lse


def _online_bwd(q, k, v, o, lse, do, seed, seg, *, causal, window, scale,
                dropout_rate, acc_dtype, chunk, unroll):
    """Chunked recompute backward — the XLA mirror of kernels/flash_bwd.py.

    Memory stays O(chunk): only (o, lse) are saved by the forward; S/P are
    recomputed per kv chunk from the stored LSE (paper §3.3)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if skv % chunk != 0:
        chunk = skv
    n_chunks = skv // chunk
    q_offset = skv - sq
    g = hq // hkv
    qf = _fold_gqa(q.astype(acc_dtype), hkv)[0]
    dof = _fold_gqa(do.astype(acc_dtype), hkv)[0]
    lsef = _fold_gqa(lse[..., None], hkv)[0][..., 0]
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    deltaf = _fold_gqa(delta[..., None], hkv)[0][..., 0]

    kc = k.reshape(b, hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    q_seg_rows = segc = None
    if seg is not None:
        seg = jnp.asarray(seg, jnp.int32)
        q_seg_rows = jnp.repeat(seg[:, q_offset:], g, axis=1)
        segc = seg.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    # fully-masked rows store lse == NEG_INF; shift so recomputed p == 0 there
    lsef_safe = jnp.where(lsef == NEG_INF, 0.0, lsef)

    def body(dq_acc, inputs):
        if seg is None:
            ci, k_blk, v_blk = inputs
            seg_blk = None
        else:
            ci, k_blk, v_blk, seg_blk = inputs
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, k_blk.astype(acc_dtype),
                       preferred_element_type=acc_dtype
                       ).astype(jnp.float32) * scale
        allowed, keep = _block_masks(b, hkv, g, sq, chunk, ci,
                                     q_offset=q_offset, causal=causal,
                                     window=window, dropout_rate=dropout_rate,
                                     dropout_seed=seed,
                                     q_seg_rows=q_seg_rows, seg_blk=seg_blk)
        if allowed is not None:
            s = jnp.where(allowed, s, NEG_INF)
        # sparklint: disable=no-inline-softmax-fold -- not a fold: backward recompute of P from the stored LSE (guard is lsef_safe above)
        p = jnp.exp(s - lsef_safe[..., None])             # recomputed probs
        p_kept = p if keep is None else \
            jnp.where(keep, p / (1.0 - dropout_rate), 0.0)
        dv_blk = jnp.einsum("bhqk,bhqd->bhkd", p_kept.astype(acc_dtype), dof,
                            preferred_element_type=acc_dtype)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dof, v_blk.astype(acc_dtype),
                        preferred_element_type=acc_dtype).astype(jnp.float32)
        if keep is not None:
            dp = jnp.where(keep, dp / (1.0 - dropout_rate), 0.0)
        ds = p * (dp - deltaf[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum(
            "bhqk,bhkd->bhqd", ds.astype(acc_dtype), k_blk.astype(acc_dtype),
            preferred_element_type=acc_dtype).astype(jnp.float32)
        dk_blk = jnp.einsum("bhqk,bhqd->bhkd", ds.astype(acc_dtype), qf,
                            preferred_element_type=acc_dtype)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, hkv, g * sq, d), jnp.float32)
    if unroll:
        dq_acc, dks, dvs = dq0, [], []
        for ci in range(n_chunks):
            inp = (jnp.int32(ci), kc[ci], vc[ci])
            dq_acc, (dkb, dvb) = body(
                dq_acc, inp if seg is None else inp + (segc[ci],))
            dks.append(dkb)
            dvs.append(dvb)
        dk_st = jnp.stack(dks)
        dv_st = jnp.stack(dvs)
    else:
        xs = (jnp.arange(n_chunks), kc, vc)
        dq_acc, (dk_st, dv_st) = jax.lax.scan(
            body, dq0, xs if seg is None else xs + (segc,))
    dq = _unfold_gqa(dq_acc, hq, sq).astype(q.dtype)
    dk = dk_st.transpose(1, 2, 0, 3, 4).reshape(b, hkv, skv, d).astype(k.dtype)
    dv = dv_st.transpose(1, 2, 0, 3, 4).reshape(b, hkv, skv, d).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _online_cv(q, k, v, seed, seg, statics):
    o, _ = _online_fwd(q, k, v, seed, seg, **dict(statics))
    return o


def _online_cv_fwd(q, k, v, seed, seg, statics):
    o, lse = _online_fwd(q, k, v, seed, seg, **dict(statics))
    return o, (q, k, v, o, lse, seed, seg)


def _online_cv_bwd(statics, res, do):
    q, k, v, o, lse, seed, seg = res
    dq, dk, dv = _online_bwd(q, k, v, o, lse, do, seed, seg, **dict(statics))
    return dq, dk, dv, None, None


_online_cv.defvjp(_online_cv_fwd, _online_cv_bwd)


def online_mha(q, k, v, *, causal: bool = False, window: Optional[int] = None,
               scale: Optional[float] = None, dropout_rate: float = 0.0,
               dropout_seed: int = 0, segment_ids=None, acc_dtype=jnp.float32,
               chunk: int = 1024, unroll: bool = False,
               return_residuals: bool = False):
    """Chunked online-softmax attention in plain XLA (the kernel's algorithm).

    O(chunk) memory in BOTH directions: the forward scans kv chunks carrying
    (m, l, acc); the custom-vjp backward recomputes S/P per chunk from the
    stored LSE exactly like kernels/flash_bwd.py — without it, differentiating
    through the scan would save the full f32 acc carry per chunk (≈5 GB/layer
    at 32k/40-head scales; found via the dry-run memory pass). GQA folds the q-head group into rows instead of expanding K/V.
    segment_ids [B, Skv] masks cross-segment pairs (packed/varlen batches).
    """
    b, hq, sq, d = q.shape
    scale = (d ** -0.5) if scale is None else scale
    statics = tuple(dict(causal=causal, window=window, scale=scale,
                         dropout_rate=dropout_rate, acc_dtype=acc_dtype,
                         chunk=chunk, unroll=unroll).items())
    seed = jnp.asarray(dropout_seed, jnp.int32)
    if return_residuals:
        return _online_fwd(q, k, v, seed, segment_ids, causal=causal,
                           window=window, scale=scale,
                           dropout_rate=dropout_rate, acc_dtype=acc_dtype,
                           chunk=chunk, unroll=unroll)
    return _online_cv(q, k, v, seed, segment_ids, statics)
