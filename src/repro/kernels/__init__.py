"""Pallas TPU kernels for the paper's fused-MHA hot spots.

The compute the paper optimizes with custom CUDA kernels, re-targeted to TPU:
``flash_fwd``/``flash_bwd`` (fused training attention), ``decode`` (contiguous
and paged flash-decode), ``rng`` (counter-based dropout bits), glued into
autodiff by ``ops`` with the two oracles in ``ref``.  The paper→kernel map
lives in docs/kernels.md.
"""
