"""Fused MHA-Forward Pallas TPU kernel (paper §3.2, adapted to MXU/VMEM).

One `pl.pallas_call` computes ``O = dropout(softmax(QKᵀ·scale))·V`` without ever
writing S or P to HBM — the paper's 3-reads + 1-write I/O profile.  The Volta
warp mechanics (m8n8k4 MMA, register layout transform between the two matmuls)
are replaced by their TPU-native equivalents:

* grid = (batch, q_head, q_block, kv_block); the kv_block dim is sequential
  ("arbitrary"), so the online-softmax state lives in VMEM scratch across
  iterations — the role the paper's registers/SRAM play on Volta.
* the S→P→(P·V) chain happens inside one kernel body; Mosaic owns the VREG
  relayout between the two `jnp.dot`s (the paper's warp-level layout transform).
* ``acc_dtype`` selects bf16-ACC / f32-ACC matmul accumulation
  (paper's FP16-ACC / FP32-ACC). Softmax state is always f32 (paper §3.2.1).
* causal / sliding-window blocks that are fully masked are skipped with
  `pl.when` (the paper's thread-block early exit).
* dropout masks are regenerated from element coordinates (kernels/rng.py), so
  the backward recompute sees identical masks with zero HBM mask traffic.

Segment-packed (varlen) batches: ``segment_ids [B, Skv]`` gives each kv token a
segment id; a token attends only within its own segment (negative ids mark
padding that attends to nothing and is attended by nothing).  The per-token ids
stream in as VMEM blocks aligned with the q/kv tiles, while per-block segment
min/max arrive via scalar-prefetch so the ``pl.when`` early exit also skips
blocks whose segment ranges cannot intersect — the same ragged-skip pattern as
``kv_len`` in kernels/decode.py.  The min/max interval test is exact-safe for
arbitrary ids (equal ids imply overlapping ranges) and tight for the packed
layout where ids are non-decreasing along the sequence.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.online_softmax import NEG_INF
from repro.kernels import rng
from repro.kernels.common import LANES, mosaic_kwargs, online_fold


def _fwd_kernel(*refs, scale: float, causal: bool, window: Optional[int],
                dropout_rate: float,
                block_q: int, block_kv: int, sq: int, skv: int,
                sq_real: int, skv_real: int, acc_dtype, segments: bool):
    if segments:
        (seed_ref, qsmin_ref, qsmax_ref, ksmin_ref, ksmax_ref,  # scalar prefetch
         q_ref, k_ref, v_ref, qseg_ref, kseg_ref,               # inputs
         o_ref, lse_ref,                                        # outputs
         acc_ref, m_ref, l_ref) = refs                          # VMEM scratch
    else:
        (seed_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
         acc_ref, m_ref, l_ref) = refs
    b, h, iq, ik = (pl.program_id(i) for i in range(4))
    nk = pl.num_programs(3)
    q_offset = skv_real - sq_real          # q tokens are the suffix of kv
    q_start = iq * block_q + q_offset      # global position of first q row
    kv_start = ik * block_kv

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # ---- block-level early exit (fully-masked blocks do no compute) ----
    needed = jnp.bool_(True)
    if causal:
        needed &= kv_start <= q_start + block_q - 1
    if window is not None:
        needed &= kv_start + block_kv - 1 > q_start - window
    if skv != skv_real:  # padded kv tail block may be entirely out of range
        needed &= kv_start < skv_real
    if segments:  # kv block's segment range must intersect the q block's
        needed &= (ksmin_ref[b, ik] <= qsmax_ref[b, iq]) & \
                  (ksmax_ref[b, ik] >= qsmin_ref[b, iq])

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0]                                     # [bq, D]
        k = k_ref[0, 0]                                     # [bkv, D]
        v = v_ref[0, 0]                                     # [bkv, D]
        # First matmul (S = Q Kᵀ) with selectable accumulate precision.
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=acc_dtype)
        s = s.astype(jnp.float32) * scale                   # softmax math in f32

        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kp = kv_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        allowed = None
        if causal:
            allowed = kp <= qp
        if window is not None:
            w_ok = kp > qp - window
            allowed = w_ok if allowed is None else (allowed & w_ok)
        if skv != skv_real:
            pad_ok = kp < skv_real
            allowed = pad_ok if allowed is None else (allowed & pad_ok)
        if segments:
            q_seg = qseg_ref[0]                             # [bq]
            kv_seg = kseg_ref[0]                            # [bkv]
            seg_ok = (q_seg[:, None] == kv_seg[None, :]) & (q_seg[:, None] >= 0)
            allowed = seg_ok if allowed is None else (allowed & seg_ok)
        if allowed is not None:
            s = jnp.where(allowed, s, NEG_INF)

        # ---- online softmax update (paper Eq. 3): the shared fold, with
        # dropout hooked between the l update (pre-dropout probabilities,
        # matching the reference softmax) and the P·V matmul ----
        p_transform = None
        if dropout_rate > 0.0:
            def p_transform(p):
                keep = rng.dropout_keep_mask(dropout_rate, seed_ref[0], b, h,
                                             qp, kp)
                return jnp.where(keep, p / (1.0 - dropout_rate), 0.0)
        online_fold(s, v, acc_ref, m_ref, l_ref, acc_dtype=acc_dtype,
                    p_transform=p_transform)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)                # fully-masked rows → 0
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[:, 0] + jnp.log(l_safe)


def _pad_segments(segment_ids, b, sq_real, skv_real, sq, skv, nq, nk,
                  block_q, block_kv):
    """Pad per-token ids to block multiples and build per-block min/max.

    Returns (q_seg [B, sq], kv_seg [B, skv], prefetch aggregates
    (qs_min, qs_max, ks_min, ks_max) each [B, n_blocks] int32).  Padding uses
    -1: negative ids never match (`seg >= 0` in the kernels), and the min/max
    interval-overlap skip stays conservative-correct with them present.
    """
    kv_seg = jnp.asarray(segment_ids, jnp.int32)
    assert kv_seg.shape == (b, skv_real), (
        f"segment_ids must be [B, Skv] = {(b, skv_real)}, got {kv_seg.shape}")
    q_seg = kv_seg[:, skv_real - sq_real:]
    if skv != skv_real:
        kv_seg = jnp.pad(kv_seg, ((0, 0), (0, skv - skv_real)),
                         constant_values=-1)
    if sq != sq_real:
        q_seg = jnp.pad(q_seg, ((0, 0), (0, sq - sq_real)), constant_values=-1)
    qs = q_seg.reshape(b, nq, block_q)
    ks = kv_seg.reshape(b, nk, block_kv)
    aggs = (qs.min(-1), qs.max(-1), ks.min(-1), ks.max(-1))
    return q_seg, kv_seg, aggs


def flash_fwd(q, k, v, *, causal: bool = False, window: Optional[int] = None,
              scale: Optional[float] = None, dropout_rate: float = 0.0,
              dropout_seed: int = 0, segment_ids=None, acc_dtype=jnp.float32,
              block_q: int = 128, block_kv: int = 128,
              interpret: bool = False):
    """Returns (o [B,Hq,Sq,D], lse [B,Hq,Sq] f32). Pads seq dims to block multiples.

    segment_ids: optional [B, Skv] int32 — per-token segment ids over the kv
    sequence (q tokens are its suffix). Attention is masked across segments;
    negative ids mark padding rows/keys that attend to nothing.
    """
    b, hq, sq_real, d = q.shape
    _, hkv, skv_real, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale

    block_q = min(block_q, max(sq_real, 8))
    block_kv = min(block_kv, max(skv_real, 8))
    sq = pl.cdiv(sq_real, block_q) * block_q
    skv = pl.cdiv(skv_real, block_kv) * block_kv
    if sq != sq_real:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq - sq_real), (0, 0)))
    if skv != skv_real:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv - skv_real), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv - skv_real), (0, 0)))

    nq, nk = sq // block_q, skv // block_kv
    segments = segment_ids is not None

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        dropout_rate=dropout_rate,
        block_q=block_q, block_kv=block_kv, sq=sq, skv=skv,
        sq_real=sq_real, skv_real=skv_real, acc_dtype=acc_dtype,
        segments=segments)

    kwargs = mosaic_kwargs(
        interpret, ("parallel", "parallel", "parallel", "arbitrary"))

    seed = jnp.atleast_1d(jnp.asarray(dropout_seed, jnp.int32))
    in_specs = [
        pl.BlockSpec((1, 1, block_q, d),
                     lambda b_, h, iq, ik, *_: (b_, h, iq, 0)),
        pl.BlockSpec((1, 1, block_kv, d),
                     lambda b_, h, iq, ik, *_: (b_, h // group, ik, 0)),
        pl.BlockSpec((1, 1, block_kv, d),
                     lambda b_, h, iq, ik, *_: (b_, h // group, ik, 0)),
    ]
    prefetch = (seed,)
    inputs = (q, k, v)
    if segments:
        q_seg, kv_seg, aggs = _pad_segments(
            segment_ids, b, sq_real, skv_real, sq, skv, nq, nk,
            block_q, block_kv)
        prefetch = prefetch + aggs
        inputs = inputs + (q_seg, kv_seg)
        in_specs += [
            pl.BlockSpec((1, block_q), lambda b_, h, iq, ik, *_: (b_, iq)),
            pl.BlockSpec((1, block_kv), lambda b_, h, iq, ik, *_: (b_, ik)),
        ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(b, hq, nq, nk),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, iq, ik, *_: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h, iq, ik, *_: (b_, h, iq)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
        ],
    )
    o, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(*prefetch, *inputs)

    if sq != sq_real:
        o = o[:, :, :sq_real]
        lse = lse[:, :, :sq_real]
    return o, lse
