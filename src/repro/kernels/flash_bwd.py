"""Fused MHA-Backward Pallas TPU kernels (paper §3.3, adapted to TPU).

The paper implements the backward as ONE kernel: each thread block owns a KV
block, iterates over Q blocks, accumulates dK/dV locally and scatters dQ with
HBM **atomic adds**.  TPUs have no HBM atomics; the TPU-idiomatic equivalent
(see docs/architecture.md) is a **dual-pass** design where each pass owns the
tensor it accumulates, and the accumulation happens race-free in VMEM scratch
across a *sequential* ("arbitrary") grid dimension:

* pass 1 (`_dkv_kernel`): grid (B, Hq, kv_block, q_block) — dK/dV accumulate in
  scratch over the q_block dim (exactly the paper's per-thread-block dK/dV
  accumulation), written once on the last q iteration.
* pass 2 (`_dq_kernel`): grid (B, Hq, q_block, kv_block) — dQ accumulates over
  the kv_block dim, replacing the atomic adds.

Both passes **recompute the forward** from Q/K (the paper's memory-saving
choice) using the stored LSE — ``p = exp(s·scale − lse)`` — so S/P never exist
in HBM.  ``delta = rowsum(dO ∘ O)`` (the paper's *dPsum*) is precomputed once.
Dropout masks are regenerated from coordinates, bit-identical to the forward.

Segment-packed (varlen) batches mirror flash_fwd.py: per-token ``segment_ids``
stream in as VMEM blocks, per-block min/max arrive via scalar-prefetch, and the
``pl.when`` early exits also skip (q-block, kv-block) pairs whose segment
ranges cannot intersect.  Negative ids mark padding (attends nothing, gets zero
gradient).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.online_softmax import NEG_INF
from repro.kernels import rng
from repro.kernels.common import mosaic_kwargs
from repro.kernels.flash_fwd import _pad_segments


def _recompute_p(q, k, lse, *, scale, causal, window, q_start, kv_start,
                 block_q, block_kv, skv_real, acc_dtype,
                 dropout_rate, dropout_seed, b, h, q_seg=None, kv_seg=None):
    """Recompute probs p [bq, bkv] (f32) + dropout keep mask from stored LSE."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=acc_dtype)
    s = s.astype(jnp.float32) * scale
    qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    kp = kv_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    allowed = None
    if causal:
        allowed = kp <= qp
    if window is not None:
        w_ok = kp > qp - window
        allowed = w_ok if allowed is None else (allowed & w_ok)
    pad_ok = kp < skv_real  # pad mask is cheap; always applied
    allowed = pad_ok if allowed is None else (allowed & pad_ok)
    if q_seg is not None:
        seg_ok = (q_seg[:, None] == kv_seg[None, :]) & (q_seg[:, None] >= 0)
        allowed = allowed & seg_ok
    if allowed is not None:
        s = jnp.where(allowed, s, NEG_INF)
    # fully-masked rows store lse == NEG_INF; exp(s - lse) would be exp(0) = 1
    # there — substitute 0 so the recomputed probs are 0 (zero gradients).
    lse_safe = jnp.where(lse == NEG_INF, 0.0, lse)
    # sparklint: disable=no-inline-softmax-fold -- not a fold: backward recompute of P from the stored LSE (guard is lse_safe above)
    p = jnp.exp(s - lse_safe[:, None])     # normalised probs, rows with lse
    keep = None
    if dropout_rate > 0.0:
        keep = rng.dropout_keep_mask(dropout_rate, dropout_seed, b, h, qp, kp)
    return p, keep


def _seg_unpack(refs, segments: bool):
    """Split the flat Pallas ref list into named groups for both bwd kernels.

    Layout: [seed, (4 seg aggregates)] + [q, k, v, do, lse, delta, (qseg, kseg)]
    + n outputs + scratch. Returns (seed, aggs, tensors, qseg, kseg, outs+scratch).
    """
    if segments:
        seed_ref, qsmin, qsmax, ksmin, ksmax = refs[:5]
        q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qseg_ref, kseg_ref = \
            refs[5:13]
        rest = refs[13:]
        aggs = (qsmin, qsmax, ksmin, ksmax)
    else:
        seed_ref = refs[0]
        q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref = refs[1:7]
        rest = refs[7:]
        aggs = qseg_ref = kseg_ref = None
    return (seed_ref, aggs, (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref),
            qseg_ref, kseg_ref, rest)


def _dkv_kernel(*refs, scale, causal, window, dropout_rate,
                block_q, block_kv, sq_real, skv_real, acc_dtype, segments):
    (seed_ref, aggs, tensors, qseg_ref, kseg_ref, rest) = \
        _seg_unpack(refs, segments)
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref = tensors
    dk_ref, dv_ref, dk_acc, dv_acc = rest
    b, h, ik, iq = (pl.program_id(i) for i in range(4))
    nq = pl.num_programs(3)
    q_offset = skv_real - sq_real
    q_start = iq * block_q + q_offset
    kv_start = ik * block_kv

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    needed = jnp.bool_(q_start < sq_real + q_offset)  # padded q tail
    if causal:
        needed &= kv_start <= q_start + block_q - 1
    if window is not None:
        needed &= kv_start + block_kv - 1 > q_start - window
    if segments:
        qsmin, qsmax, ksmin, ksmax = aggs
        needed &= (ksmin[b, ik] <= qsmax[b, iq]) & (ksmax[b, ik] >= qsmin[b, iq])

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0]          # [bq, D]
        k = k_ref[0, 0]          # [bkv, D]
        v = v_ref[0, 0]
        do = do_ref[0, 0]        # [bq, D]
        lse = lse_ref[0, 0]      # [bq] f32
        delta = delta_ref[0, 0]  # [bq] f32

        p, keep = _recompute_p(
            q, k, lse, scale=scale, causal=causal, window=window,
            q_start=q_start, kv_start=kv_start, block_q=block_q,
            block_kv=block_kv, skv_real=skv_real, acc_dtype=acc_dtype,
            dropout_rate=dropout_rate, dropout_seed=seed_ref[0], b=b, h=h,
            q_seg=None if qseg_ref is None else qseg_ref[0],
            kv_seg=None if kseg_ref is None else kseg_ref[0])

        p_kept = p if keep is None else jnp.where(keep, p / (1.0 - dropout_rate), 0.0)
        # dV += P̃ᵀ · dO
        dv_acc[...] += jax.lax.dot_general(
            p_kept.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=acc_dtype).astype(jnp.float32)
        # dP = dO · Vᵀ  (masked by the same dropout keep-mask)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=acc_dtype).astype(jnp.float32)
        if keep is not None:
            dp = jnp.where(keep, dp / (1.0 - dropout_rate), 0.0)
        # dS = P ∘ (dP − delta) · scale   (delta = paper's dPsum)
        ds = p * (dp - delta[:, None]) * scale
        # dK += dSᵀ · Q
        dk_acc[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=acc_dtype).astype(jnp.float32)

    @pl.when(iq == nq - 1)
    def _write():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _dq_kernel(*refs, scale, causal, window, dropout_rate,
               block_q, block_kv, sq_real, skv_real, acc_dtype, segments):
    (seed_ref, aggs, tensors, qseg_ref, kseg_ref, rest) = \
        _seg_unpack(refs, segments)
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref = tensors
    dq_ref, dq_acc = rest
    b, h, iq, ik = (pl.program_id(i) for i in range(4))
    nk = pl.num_programs(3)
    q_offset = skv_real - sq_real
    q_start = iq * block_q + q_offset
    kv_start = ik * block_kv

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    needed = jnp.bool_(kv_start < skv_real)
    if causal:
        needed &= kv_start <= q_start + block_q - 1
    if window is not None:
        needed &= kv_start + block_kv - 1 > q_start - window
    if segments:
        qsmin, qsmax, ksmin, ksmax = aggs
        needed &= (ksmin[b, ik] <= qsmax[b, iq]) & (ksmax[b, ik] >= qsmin[b, iq])

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        v = v_ref[0, 0]
        do = do_ref[0, 0]
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]

        p, keep = _recompute_p(
            q, k, lse, scale=scale, causal=causal, window=window,
            q_start=q_start, kv_start=kv_start, block_q=block_q,
            block_kv=block_kv, skv_real=skv_real, acc_dtype=acc_dtype,
            dropout_rate=dropout_rate, dropout_seed=seed_ref[0], b=b, h=h,
            q_seg=None if qseg_ref is None else qseg_ref[0],
            kv_seg=None if kseg_ref is None else kseg_ref[0])

        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=acc_dtype).astype(jnp.float32)
        if keep is not None:
            dp = jnp.where(keep, dp / (1.0 - dropout_rate), 0.0)
        ds = p * (dp - delta[:, None]) * scale
        # dQ += dS · K   — VMEM-scratch accumulation replaces the paper's atomics
        dq_acc[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype).astype(jnp.float32)

    @pl.when(ik == nk - 1)
    def _write():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def flash_bwd(q, k, v, o, lse, do, *, causal: bool = False,
              window: Optional[int] = None, scale: Optional[float] = None,
              dropout_rate: float = 0.0, dropout_seed: int = 0,
              segment_ids=None, acc_dtype=jnp.float32,
              block_q: int = 128, block_kv: int = 128,
              interpret: bool = False):
    """Returns (dq, dk, dv) with the shapes/dtypes of q, k, v."""
    b, hq, sq_real, d = q.shape
    _, hkv, skv_real, _ = k.shape
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale

    # delta = rowsum(dO ∘ O) — the paper's dPsum, precomputed once (f32).
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    block_q = min(block_q, max(sq_real, 8))
    block_kv = min(block_kv, max(skv_real, 8))
    sq = pl.cdiv(sq_real, block_q) * block_q
    skv = pl.cdiv(skv_real, block_kv) * block_kv
    if sq != sq_real:
        pad_q = ((0, 0), (0, 0), (0, sq - sq_real), (0, 0))
        q = jnp.pad(q, pad_q)
        do = jnp.pad(do, pad_q)
        # padded rows: lse=+inf would give p=0; use large positive to zero probs
        lse = jnp.pad(lse, ((0, 0), (0, 0), (0, sq - sq_real)),
                      constant_values=-NEG_INF)
        delta = jnp.pad(delta, ((0, 0), (0, 0), (0, sq - sq_real)))
    if skv != skv_real:
        pad_kv = ((0, 0), (0, 0), (0, skv - skv_real), (0, 0))
        k = jnp.pad(k, pad_kv)
        v = jnp.pad(v, pad_kv)

    nq, nk = sq // block_q, skv // block_kv
    segments = segment_ids is not None
    common = dict(scale=scale, causal=causal, window=window,
                  dropout_rate=dropout_rate,
                  block_q=block_q, block_kv=block_kv,
                  sq_real=sq_real, skv_real=skv_real, acc_dtype=acc_dtype,
                  segments=segments)

    q_spec = pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j, *_: (b_, h, j, 0))
    kv_spec = pl.BlockSpec((1, 1, block_kv, d),
                           lambda b_, h, i, j, *_: (b_, h // group, i, 0))
    row_spec = pl.BlockSpec((1, 1, block_q), lambda b_, h, i, j, *_: (b_, h, j))

    kwargs = mosaic_kwargs(
        interpret, ("parallel", "parallel", "parallel", "arbitrary"))

    seed = jnp.atleast_1d(jnp.asarray(dropout_seed, jnp.int32))
    prefetch = (seed,)
    seg_inputs = ()
    if segments:
        q_seg, kv_seg, aggs = _pad_segments(
            segment_ids, b, sq_real, skv_real, sq, skv, nq, nk,
            block_q, block_kv)
        prefetch = prefetch + aggs
        seg_inputs = (q_seg, kv_seg)

    # ---- pass 1: dK, dV (per q-head; GQA groups reduced below) ----
    in_specs1 = [q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec]
    if segments:
        in_specs1 += [
            pl.BlockSpec((1, block_q), lambda b_, h, i, j, *_: (b_, j)),
            pl.BlockSpec((1, block_kv), lambda b_, h, i, j, *_: (b_, i)),
        ]
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, **common),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b, hq, nk, nq),
            in_specs=in_specs1,
            out_specs=[
                pl.BlockSpec((1, 1, block_kv, d),
                             lambda b_, h, i, j, *_: (b_, h, i, 0)),
                pl.BlockSpec((1, 1, block_kv, d),
                             lambda b_, h, i, j, *_: (b_, h, i, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((block_kv, d), jnp.float32),
                            pltpu.VMEM((block_kv, d), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, skv, d), k.dtype),
            jax.ShapeDtypeStruct((b, hq, skv, d), v.dtype),
        ],
        interpret=interpret,
        **kwargs,
    )(*prefetch, q, k, v, do, lse, delta, *seg_inputs)

    # ---- pass 2: dQ ----
    q_spec2 = pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j, *_: (b_, h, i, 0))
    kv_spec2 = pl.BlockSpec((1, 1, block_kv, d),
                            lambda b_, h, i, j, *_: (b_, h // group, j, 0))
    row_spec2 = pl.BlockSpec((1, 1, block_q), lambda b_, h, i, j, *_: (b_, h, i))
    in_specs2 = [q_spec2, kv_spec2, kv_spec2, q_spec2, row_spec2, row_spec2]
    if segments:
        in_specs2 += [
            pl.BlockSpec((1, block_q), lambda b_, h, i, j, *_: (b_, i)),
            pl.BlockSpec((1, block_kv), lambda b_, h, i, j, *_: (b_, j)),
        ]
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b, hq, nq, nk),
            in_specs=in_specs2,
            out_specs=pl.BlockSpec((1, 1, block_q, d),
                                   lambda b_, h, i, j, *_: (b_, h, i, 0)),
            scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        interpret=interpret,
        **kwargs,
    )(*prefetch, q, k, v, do, lse, delta, *seg_inputs)

    if sq != sq_real:
        dq = dq[:, :, :sq_real]
    if skv != skv_real:
        dk = dk[:, :, :skv_real]
        dv = dv[:, :, :skv_real]
    if group > 1:  # GQA: reduce the per-q-head dK/dV over each group
        dk = dk.reshape(b, hkv, group, skv_real, d).sum(axis=2).astype(k.dtype)
        dv = dv.reshape(b, hkv, group, skv_real, d).sum(axis=2).astype(v.dtype)
    return dq, dk, dv
