"""Counter-based dropout RNG usable inside Pallas kernel bodies.

The paper applies dropout *inside* the fused kernel and replays the identical
mask during the backward recompute ("we apply the same dropout logic as in the
MHA-Forward process to obtain consistent dropout results").  CUDA does this with
curand seeded per thread; on TPU (and in interpret mode) we instead derive the
mask *functionally* from the element's global coordinates, so forward and the
two backward passes regenerate bit-identical masks with zero HBM traffic.

This is a small Philox-inspired integer hash (3 rounds of multiply/xor-shift
mixing) over (seed, batch, head, q_position, kv_position).  It is built from
plain int32 vector ops only, so it lowers on Mosaic/TPU, XLA:CPU, and in Pallas
interpret mode identically.  It is a *dropout-grade* generator (decorrelated,
uniform-ish), not a cryptographic one.
"""

from __future__ import annotations

import jax.numpy as jnp

# odd 32-bit mixing constants (from splitmix64 / murmur3 finalizers).
# Kept as plain python ints: Pallas kernel bodies may not close over arrays.
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35
_M3 = 0x27D4EB2F
_GOLDEN = 0x9E3779B9


def _mix(x: jnp.ndarray) -> jnp.ndarray:
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> 16)
    return x


def random_bits(seed, b, h, q_pos, kv_pos) -> jnp.ndarray:
    """uint32 bits for each (q_pos, kv_pos) pair.

    ``q_pos [rows, 1]`` and ``kv_pos [1, cols]`` are int32 index grids (global
    positions, so the mask is invariant to the block decomposition); ``seed``,
    ``b``, ``h`` are scalars. Returns uint32 [rows, cols].
    """
    seed = jnp.asarray(seed).astype(jnp.uint32)
    b = jnp.asarray(b).astype(jnp.uint32)
    h = jnp.asarray(h).astype(jnp.uint32)
    s = (seed * jnp.uint32(_GOLDEN) + b * jnp.uint32(_M3)) ^ (h + jnp.uint32(_GOLDEN))
    x = (q_pos.astype(jnp.uint32) * jnp.uint32(_M1)
         + kv_pos.astype(jnp.uint32) * jnp.uint32(_M2) + s)
    x = _mix(x)
    x = _mix(x * jnp.uint32(_M3) + jnp.uint32(_GOLDEN))
    return x


def dropout_keep_mask(rate: float, seed, b, h, q_pos, kv_pos) -> jnp.ndarray:
    """Boolean keep-mask with P(keep) = 1 - rate, reproducible from coordinates."""
    bits = random_bits(seed, b, h, q_pos, kv_pos)
    # keep iff bits >= rate * 2^32  (compare in uint32 space)
    threshold = jnp.uint32(min(int(rate * 4294967296.0), 4294967295))
    return bits >= threshold
