"""Shared Pallas kernel machinery: the online-softmax fold + launch boilerplate.

Every attention kernel in this package folds blocks of masked scores into the
same VMEM ``(m, l, acc)`` scratch state (paper Eq. 2) — the forward kernel,
the contiguous/paged decode kernels and the split/partial decode kernels used
by distributed serving. The fold used to live as three near-copies (one of
which silently lacked the fully-masked-row ``m == NEG_INF`` guard); this
module is now the single in-kernel counterpart of the pure-array algebra in
``core/online_softmax.py``.

It also owns the ``pallas_call`` launch boilerplate (CompilerParams /
interpret-mode switch) that every kernel wrapper previously re-spelled.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

from repro.core.online_softmax import NEG_INF

LANES = 128  # TPU vector lane width; (rows, LANES) f32 scratch for m/l state


def mosaic_kwargs(interpret: bool,
                  dimension_semantics: Sequence[str]) -> Dict:
    """``pallas_call`` kwargs for the Mosaic compiler.

    Interpret mode (CPU validation) takes no compiler params; on hardware the
    grid's ``dimension_semantics`` mark which axes may run in parallel and
    which carry scratch state sequentially ("arbitrary"). One helper instead
    of the same four-line conditional in every kernel wrapper.
    """
    if interpret:
        return {}
    return {"compiler_params": pltpu.CompilerParams(
        dimension_semantics=tuple(dimension_semantics))}


def online_fold(s, v, acc_ref, m_ref, l_ref, *, acc_dtype,
                p_transform: Optional[Callable] = None):
    """Fold one masked score block into the VMEM ``(m, l, acc)`` scratch state.

    The in-kernel form of ``online_softmax.update`` (paper Eq. 2): ``s`` is
    the f32 ``[rows, block]`` score tile with disallowed positions already set
    to ``NEG_INF``; ``v`` is the matching ``[block, D]`` value tile. ``m_ref``
    and ``l_ref`` are ``[rows, LANES]`` f32 scratch (column 0 authoritative),
    ``acc_ref`` is ``[rows, D]`` f32 scratch.

    Rows that have only ever seen masked scores keep ``m == NEG_INF``; there
    ``exp(s - m)`` would be ``exp(0) = 1``, silently counting masked
    positions. The ``m_safe`` substitution zeroes those probabilities so ``l``
    stays 0 and the caller's ``l == 0`` finalize guard emits exact zeros
    (fully-masked rows: packed-batch padding, ``kv_len == 0`` decode rows).

    ``p_transform`` hooks between the ``l`` update and the ``P·V`` matmul —
    the forward kernel applies dropout there (``l`` must see pre-dropout
    probabilities, matching the reference softmax).
    """
    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)                     # rescale of old state
    m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])                    # unnormalised probs
    l_ref[...] = jnp.broadcast_to(
        (l_prev * alpha + jnp.sum(p, axis=1))[:, None], l_ref.shape)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    if p_transform is not None:
        p = p_transform(p)
    # P downcast to the value dtype for the MXU (the paper's MMA-C → MMA-A
    # layout transform happens here on Volta; Mosaic owns the VREG relayout)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=acc_dtype)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv.astype(jnp.float32)
