"""Flash-decode Pallas TPU kernel: one new query token vs. a long KV cache.

The paper's fused-MHA dataflow applied to the inference-decode shape
(``decode_32k`` / ``long_500k``): a single query row per (batch, kv-head)
streams the KV cache HBM→VMEM once, maintaining online-softmax state in VMEM
scratch.  This is purely memory-bound on TPU — the roofline term that matters
is HBM bytes = bytes(K) + bytes(V), which this kernel achieves exactly (the
naive path reads K, writes S, reads S, writes P, reads P and V: 3× more).

GQA: the ``G = Hq // Hkv`` query heads sharing one KV head are batched into the
MXU ``M`` dimension, so the two matmuls are [G,D]×[D,bkv] and [G,bkv]×[bkv,D] —
the TPU analogue of the paper packing multiple MMA computations per warp.

Ragged batches: ``kv_len [B]`` (scalar-prefetch) masks each row's valid cache
length, and fully-out-of-range KV blocks are skipped with ``pl.when``.

Split-KV (``num_splits > 1``): the sequential online-softmax loop over KV
blocks exposes only ``B·Hkv`` parallel work items — at serving shapes (small
continuous-batching batches, very long caches) that leaves most of the chip
idle.  The grid gains a *splits* axis: split ``s`` folds its contiguous slice
of KV blocks into its own un-normalised ``(acc, m, l)`` state (the same
partial-state trick the distributed path uses per shard), and a tiny
vectorized ``online_softmax.merge_many`` + ``finalize`` combines the splits —
``B·Hkv·num_splits`` parallel items for one extra O(B·Hq·D) merge pass.
``perf/autotune.py`` picks ``(num_splits, block_kv)`` from a cost model.

One kernel body (:func:`_decode_body`) serves every variant — contiguous,
paged, finalized or partial-state — parameterized by the scalar-prefetch
wrappers below; the fold itself is ``kernels.common.online_fold``.

Paged variant (:func:`flash_paged_decode`): the KV cache is a pool of
fixed-size pages ``[Hkv, num_pages, page_size, D]`` shared by all sequences;
each row's scalar-prefetched *block table* ``[B, T]`` names the physical page
backing its ``ik``-th logical KV block.  The page id feeds straight into the
K/V BlockSpec index map, so Mosaic's pipeline DMA gathers exactly the pages a
row owns HBM→VMEM — the kernel body is the same online-softmax loop with
``block_kv = page_size``.  Freed/unassigned table entries must point at a
valid page (the pool reserves page 0 as a trash page): the index map runs for
skipped blocks too, only the compute is gated by ``pl.when``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import online_softmax as osm
from repro.core.online_softmax import NEG_INF
from repro.kernels.common import LANES, mosaic_kwargs, online_fold

# grid axes of every decode kernel: (batch, kv_head, split, kv-block-in-split)
_DECODE_SEMANTICS = ("parallel", "parallel", "parallel", "arbitrary")


def _decode_body(kv_len_ref, valid_ref, q_ref, k_ref, v_ref, rest, *,
                 scale: float, window: Optional[int], block_kv: int,
                 num_blocks: Optional[int], acc_dtype, finalize: bool):
    """The one decode loop body behind every kernel variant.

    Grid is always ``(B, Hkv, num_splits, blocks_per_split)``: program (b, h,
    s, j) folds global KV block ``ik = s·blocks_per_split + j`` into the
    (m, l, acc) scratch carried across the sequential ``j`` axis.  With
    ``finalize`` the last ``j`` writes the normalised output (valid only for
    ``num_splits == 1``); otherwise each split writes its raw state triple,
    merged by the caller (``online_softmax.merge_many``) — the same algebra
    the distributed path uses across shards.

    ``valid_ref [B, T]`` (optional) gates blocks the caller does not own
    (distributed pool shards); ``num_blocks`` gates trailing blocks past the
    real block count when the split layout over-covers (paged tables whose
    width does not divide by the split count).
    """
    *outs, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(3)
    nj = pl.num_programs(3)
    ik = pl.program_id(2) * nj + j                 # global KV block index
    kv_start = ik * block_kv
    kv_len = kv_len_ref[b]                         # valid cache length, this row
    q_pos = kv_len - 1                             # the query token's position

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    needed = kv_start < kv_len
    if num_blocks is not None:
        needed &= ik < num_blocks
    if valid_ref is not None:
        # clamp like the block-table index map: over-cover cells (ik >=
        # num_blocks) are compute-gated above but still evaluate this read
        needed &= valid_ref[b, jnp.minimum(ik, num_blocks - 1)] != 0
    if window is not None:
        needed &= kv_start + block_kv - 1 > q_pos - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0]                            # [G, D]
        k = k_ref[0, 0]                            # [bkv, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=acc_dtype)
        s = s.astype(jnp.float32) * scale          # [G, bkv]
        kp = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        allowed = kp < kv_len
        if window is not None:
            allowed &= kp > q_pos - window
        s = jnp.where(allowed, s, NEG_INF)
        online_fold(s, v_ref[0, 0], acc_ref, m_ref, l_ref, acc_dtype=acc_dtype)

    @pl.when(j == nj - 1)
    def _write():
        if finalize:
            (o_ref,) = outs
            l = l_ref[:, 0]
            l_safe = jnp.where(l == 0.0, 1.0, l)
            o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        else:
            acc_out, m_out, l_out = outs
            acc_out[0, 0, 0] = acc_ref[...].astype(acc_out.dtype)
            m_out[0, 0, 0] = m_ref[...].astype(m_out.dtype)
            l_out[0, 0, 0] = l_ref[...].astype(l_out.dtype)


def _contig_kernel(kv_len_ref, q_ref, k_ref, v_ref, *rest, **kw):
    # contiguous cache: kv_len is the only scalar-prefetch operand
    _decode_body(kv_len_ref, None, q_ref, k_ref, v_ref, rest, **kw)


def _paged_kernel(kv_len_ref, bt_ref, q_ref, k_ref, v_ref, *rest, **kw):
    # the block table is consumed entirely by the K/V BlockSpec index maps;
    # inside the body the gathered page is indistinguishable from a contiguous
    # cache block, so the loop is shared with the contiguous kernel
    del bt_ref
    _decode_body(kv_len_ref, None, q_ref, k_ref, v_ref, rest, **kw)


def _paged_valid_kernel(kv_len_ref, bt_ref, valid_ref, q_ref, k_ref, v_ref,
                        *rest, **kw):
    # blocks with valid_ref[b, ik] == 0 are skipped entirely: the distributed
    # path marks non-local table entries invalid (they point at the local
    # trash page)
    del bt_ref
    _decode_body(kv_len_ref, valid_ref, q_ref, k_ref, v_ref, rest, **kw)


def _group_pad(q, b, hkv, group, d):
    """[B, Hq, D] → [B, Hkv, G_pad, D] with G padded up to the 8-row MXU tile."""
    qg = q.reshape(b, hkv, group, d)
    g_pad = max(8, group)
    if g_pad != group:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - group), (0, 0)))
    return qg, g_pad


def _decode_out_shapes(b, hkv, ns, g_pad, d, out_dtype, finalize: bool):
    """(out_shape, out_specs) for the finalized / partial kernel variants.

    The index maps absorb trailing scalar-prefetch refs with ``*_``; partial
    outputs carry the splits axis so every (b, h, split) cell writes its own
    state block.
    """
    def _ix(b_, h, s_, j, *_):
        return (b_, h, 0, 0)

    def _ix_split(b_, h, s_, j, *_):
        return (b_, h, s_, 0, 0)

    if finalize:
        return (jax.ShapeDtypeStruct((b, hkv, g_pad, d), out_dtype),
                pl.BlockSpec((1, 1, g_pad, d), _ix))
    out_shape = [jax.ShapeDtypeStruct((b, hkv, ns, g_pad, d), jnp.float32),
                 jax.ShapeDtypeStruct((b, hkv, ns, g_pad, LANES), jnp.float32),
                 jax.ShapeDtypeStruct((b, hkv, ns, g_pad, LANES), jnp.float32)]
    out_specs = [pl.BlockSpec((1, 1, 1, g_pad, d), _ix_split),
                 pl.BlockSpec((1, 1, 1, g_pad, LANES), _ix_split),
                 pl.BlockSpec((1, 1, 1, g_pad, LANES), _ix_split)]
    return out_shape, out_specs


def _split_states(acc, m, l, group, b, hq):
    """Kernel partial outputs → a SoftmaxState stacked on the splits axis.

    acc [B,Hkv,ns,G_pad,D], m/l [B,Hkv,ns,G_pad,LANES] → state with
    m/l [B,ns,Hq] and acc [B,ns,Hq,D] (splits axis 1, ready for merge_many).
    """
    ns, d = acc.shape[2], acc.shape[-1]
    acc = acc[:, :, :, :group].transpose(0, 2, 1, 3, 4).reshape(b, ns, hq, d)
    m = m[:, :, :, :group, 0].transpose(0, 2, 1, 3).reshape(b, ns, hq)
    l = l[:, :, :, :group, 0].transpose(0, 2, 1, 3).reshape(b, ns, hq)
    return osm.SoftmaxState(m=m, l=l, acc=acc)


def flash_decode(q, k, v, *, kv_len=None, window: Optional[int] = None,
                 scale: Optional[float] = None, acc_dtype=jnp.float32,
                 block_kv: int = 512, num_splits: int = 1,
                 interpret: bool = False):
    """q: [B, Hq, D]; k/v: [B, Hkv, S, D]; kv_len: [B] int32 (default: full S).

    num_splits > 1 partitions the KV axis across that many parallel grid
    cells, each producing an un-normalised partial state, merged in f32 by
    ``online_softmax.merge_many`` (module docstring). Returns o: [B, Hq, D]
    in q.dtype.
    """
    b, hq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    if kv_len is None:
        kv_len = jnp.full((b,), skv, jnp.int32)

    # clamp the block to the cache length, but keep KV tiles 8-row aligned:
    # a short cache (skv < 8) must not produce a sub-8-row tile — pad instead
    block_kv = min(block_kv, max(skv, 8))
    block_kv = -(-block_kv // 8) * 8
    nk = pl.cdiv(skv, block_kv)
    num_splits = max(1, min(num_splits, nk))
    nj = pl.cdiv(nk, num_splits)                   # KV blocks per split
    skv_pad = nk * block_kv                        # remainder pad only —
    if skv_pad != skv:                             # split over-cover cells
        pad = ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0))  # (ik >= nk) are
        k = jnp.pad(k, pad)                        # compute-gated + index-
        v = jnp.pad(v, pad)                        # clamped, no data needed

    qg, g_pad = _group_pad(q, b, hkv, group, d)
    finalize = num_splits == 1

    def _kv_ix(b_, h, s_, j, *_):
        return (b_, h, jnp.minimum(s_ * nj + j, nk - 1), 0)

    kernel = functools.partial(_contig_kernel, scale=scale, window=window,
                               block_kv=block_kv, num_blocks=nk,
                               acc_dtype=acc_dtype, finalize=finalize)
    out_shape, out_specs = _decode_out_shapes(
        b, hkv, num_splits, g_pad, d, q.dtype, finalize)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, num_splits, nj),
        in_specs=[
            pl.BlockSpec((1, 1, g_pad, d),
                         lambda b_, h, s_, j, *_: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d), _kv_ix),
            pl.BlockSpec((1, 1, block_kv, d), _kv_ix),
        ],
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((g_pad, d), jnp.float32),
                        pltpu.VMEM((g_pad, LANES), jnp.float32),
                        pltpu.VMEM((g_pad, LANES), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        **mosaic_kwargs(interpret, _DECODE_SEMANTICS),
    )(kv_len.astype(jnp.int32), qg, k, v)
    if finalize:
        return out[:, :, :group].reshape(b, hq, d)
    state = osm.merge_many(_split_states(*out, group, b, hq), axis=1)
    o, _ = osm.finalize(state, out_dtype=q.dtype)
    return o


def _paged_call(kernel_fn, prefetch, qg, k_pages, v_pages, *, b, hkv, ns, nj,
                t, g_pad, d, page_size, out_dtype, finalize, scale, window,
                acc_dtype, interpret):
    """Shared pallas_call launch for the paged variants (finalized/partial).

    ``prefetch`` is the scalar-prefetch tuple starting with (kv_len,
    block_tables[, block_valid]); the K/V index maps read the table at the
    global block index ``s·nj + j`` (clamped — trailing cells past the table
    width are compute-gated by ``num_blocks``).
    """
    n_pre = len(prefetch)

    def _kv_ix(b_, h, s_, j, kvl, bt, *_):
        ik = jnp.minimum(s_ * nj + j, t - 1)
        return (h, bt[b_, ik], 0, 0)

    kernel = functools.partial(kernel_fn, scale=scale, window=window,
                               block_kv=page_size, num_blocks=t,
                               acc_dtype=acc_dtype, finalize=finalize)
    out_shape, out_specs = _decode_out_shapes(
        b, hkv, ns, g_pad, d, out_dtype, finalize)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_pre,
        grid=(b, hkv, ns, nj),
        in_specs=[
            pl.BlockSpec((1, 1, g_pad, d),
                         lambda b_, h, s_, j, *_: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d), _kv_ix),
            pl.BlockSpec((1, 1, page_size, d), _kv_ix),
        ],
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((g_pad, d), jnp.float32),
                        pltpu.VMEM((g_pad, LANES), jnp.float32),
                        pltpu.VMEM((g_pad, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        **mosaic_kwargs(interpret, _DECODE_SEMANTICS),
    )(*prefetch, qg, k_pages, v_pages)


def flash_paged_decode(q, k_pages, v_pages, block_tables, kv_len, *,
                       window: Optional[int] = None,
                       scale: Optional[float] = None, acc_dtype=jnp.float32,
                       num_splits: int = 1, interpret: bool = False):
    """Flash-decode against a paged KV cache.

    q: [B, Hq, D]; k_pages/v_pages: [Hkv, num_pages, page_size, D] (global page
    pool); block_tables: [B, T] int32 physical page ids per logical KV block
    (entries past a row's allocation must still be valid ids — use the pool's
    trash page 0); kv_len: [B] int32 valid cache length per row. num_splits
    partitions the table width T across parallel grid cells (module
    docstring).

    Returns o: [B, Hq, D] in q.dtype.
    """
    b, hq, d = q.shape
    hkv, _, page_size, _ = k_pages.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    t = block_tables.shape[1]
    num_splits = max(1, min(num_splits, t))
    nj = pl.cdiv(t, num_splits)
    qg, g_pad = _group_pad(q, b, hkv, group, d)
    finalize = num_splits == 1

    prefetch = (kv_len.astype(jnp.int32), block_tables.astype(jnp.int32))
    out = _paged_call(_paged_kernel, prefetch, qg, k_pages, v_pages,
                      b=b, hkv=hkv, ns=num_splits, nj=nj, t=t, g_pad=g_pad,
                      d=d, page_size=page_size, out_dtype=q.dtype,
                      finalize=finalize, scale=scale, window=window,
                      acc_dtype=acc_dtype, interpret=interpret)
    if finalize:
        return out[:, :, :group].reshape(b, hq, d)
    state = osm.merge_many(_split_states(*out, group, b, hq), axis=1)
    o, _ = osm.finalize(state, out_dtype=q.dtype)
    return o


def flash_paged_decode_partials(q, k_pages, v_pages, block_tables, kv_len, *,
                                block_valid=None, window: Optional[int] = None,
                                scale: Optional[float] = None,
                                acc_dtype=jnp.float32, num_splits: int = 1,
                                interpret: bool = False):
    """Paged flash-decode returning the un-finalized online-softmax state.

    Same arguments as :func:`flash_paged_decode` plus ``block_valid [B, T]``
    (int32/bool; 0 marks table entries this caller does not own — the
    distributed path passes the locality mask of its pool shard and remaps
    those entries to its local trash page).  Returns the f32 triple
    ``(acc [B,Hq,D], m [B,Hq], l [B,Hq])`` for ``online_softmax.merge`` /
    ``finalize`` — shards of a page-sharded pool each compute their local
    state, then a tiny all-reduce merges them (distributed paged serving).
    With ``num_splits > 1`` the shard-local splits are merged locally first
    (``merge_many``), composing with the cross-shard merge — the returned
    triple is identical either way.
    """
    b, hq, d = q.shape
    hkv, _, page_size, _ = k_pages.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    t = block_tables.shape[1]
    num_splits = max(1, min(num_splits, t))
    nj = pl.cdiv(t, num_splits)
    if block_valid is None:
        block_valid = jnp.ones((b, t), jnp.int32)
    qg, g_pad = _group_pad(q, b, hkv, group, d)

    prefetch = (kv_len.astype(jnp.int32), block_tables.astype(jnp.int32),
                block_valid.astype(jnp.int32))
    acc, m, l = _paged_call(_paged_valid_kernel, prefetch, qg, k_pages,
                            v_pages, b=b, hkv=hkv, ns=num_splits, nj=nj, t=t,
                            g_pad=g_pad, d=d, page_size=page_size,
                            out_dtype=jnp.float32, finalize=False,
                            scale=scale, window=window, acc_dtype=acc_dtype,
                            interpret=interpret)
    state = osm.merge_many(_split_states(acc, m, l, group, b, hq), axis=1)
    return state.acc, state.m, state.l
