"""Flash-decode Pallas TPU kernel: one new query token vs. a long KV cache.

The paper's fused-MHA dataflow applied to the inference-decode shape
(``decode_32k`` / ``long_500k``): a single query row per (batch, kv-head)
streams the KV cache HBM→VMEM once, maintaining online-softmax state in VMEM
scratch.  This is purely memory-bound on TPU — the roofline term that matters
is HBM bytes = bytes(K) + bytes(V), which this kernel achieves exactly (the
naive path reads K, writes S, reads S, writes P, reads P and V: 3× more).

GQA: the ``G = Hq // Hkv`` query heads sharing one KV head are batched into the
MXU ``M`` dimension, so the two matmuls are [G,D]×[D,bkv] and [G,bkv]×[bkv,D] —
the TPU analogue of the paper packing multiple MMA computations per warp.

Ragged batches: ``kv_len [B]`` (scalar-prefetch) masks each row's valid cache
length, and fully-out-of-range KV blocks are skipped with ``pl.when``.

Paged variant (:func:`flash_paged_decode`): the KV cache is a pool of
fixed-size pages ``[Hkv, num_pages, page_size, D]`` shared by all sequences;
each row's scalar-prefetched *block table* ``[B, T]`` names the physical page
backing its ``ik``-th logical KV block.  The page id feeds straight into the
K/V BlockSpec index map, so Mosaic's pipeline DMA gathers exactly the pages a
row owns HBM→VMEM — the kernel body is the same online-softmax loop with
``block_kv = page_size``.  Freed/unassigned table entries must point at a
valid page (the pool reserves page 0 as a trash page): the index map runs for
skipped blocks too, only the compute is gated by ``pl.when``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.online_softmax import NEG_INF

LANES = 128


def _online_block(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, kv_start,
                  kv_len, q_pos, *, scale, window, acc_dtype):
    """Fold one KV block into the (m, l, acc) scratch state (paper Eq. 2)."""
    q = q_ref[0, 0]                            # [G, D]
    k = k_ref[0, 0]                            # [bkv, D]
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=acc_dtype)
    s = s.astype(jnp.float32) * scale          # [G, bkv]
    kp = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    allowed = kp < kv_len
    if window is not None:
        allowed &= kp > q_pos - window
    s = jnp.where(allowed, s, NEG_INF)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = jnp.broadcast_to((l_prev * alpha + jnp.sum(p, axis=1))[:, None],
                                  l_ref.shape)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=acc_dtype)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv.astype(jnp.float32)


def _decode_kernel(kv_len_ref,                    # scalar prefetch [B]
                   q_ref, k_ref, v_ref,           # inputs
                   o_ref,                         # output
                   acc_ref, m_ref, l_ref,         # scratch
                   *, scale: float, window: Optional[int], block_kv: int,
                   acc_dtype):
    b, hk, ik = (pl.program_id(i) for i in range(3))
    nk = pl.num_programs(2)
    kv_start = ik * block_kv
    kv_len = kv_len_ref[b]                         # valid cache length, this row
    q_pos = kv_len - 1                             # the query token's position

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    needed = kv_start < kv_len
    if window is not None:
        needed &= kv_start + block_kv - 1 > q_pos - window

    @pl.when(needed)
    def _compute():
        _online_block(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, kv_start,
                      kv_len, q_pos, scale=scale, window=window,
                      acc_dtype=acc_dtype)

    @pl.when(ik == nk - 1)
    def _write():
        l = l_ref[:, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(kv_len_ref, bt_ref, *rest, **kw):
    # The block table is consumed entirely by the K/V BlockSpec index maps;
    # inside the body the gathered page is indistinguishable from a contiguous
    # cache block, so the online-softmax loop is shared with _decode_kernel.
    del bt_ref
    _decode_kernel(kv_len_ref, *rest, **kw)


def _paged_partial_kernel(kv_len_ref, bt_ref, valid_ref,  # scalar prefetch
                          q_ref, k_ref, v_ref,            # inputs
                          acc_out_ref, m_out_ref, l_out_ref,   # outputs
                          acc_ref, m_ref, l_ref,          # scratch
                          *, scale: float, window: Optional[int],
                          block_kv: int, acc_dtype):
    """Partial-state paged decode: like _paged_decode_kernel, but (a) blocks
    whose ``valid_ref[b, ik] == 0`` are skipped entirely (the distributed path
    marks non-local table entries invalid; they point at the local trash page)
    and (b) the un-normalised (acc, m, l) state is written out instead of
    ``acc / l`` — the caller merges states across shards (online_softmax.merge)
    and finalizes once."""
    del bt_ref
    b, hk, ik = (pl.program_id(i) for i in range(3))
    nk = pl.num_programs(2)
    kv_start = ik * block_kv
    kv_len = kv_len_ref[b]
    q_pos = kv_len - 1

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    needed = (kv_start < kv_len) & (valid_ref[b, ik] != 0)
    if window is not None:
        needed &= kv_start + block_kv - 1 > q_pos - window

    @pl.when(needed)
    def _compute():
        _online_block(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, kv_start,
                      kv_len, q_pos, scale=scale, window=window,
                      acc_dtype=acc_dtype)

    @pl.when(ik == nk - 1)
    def _write():
        acc_out_ref[0, 0] = acc_ref[...].astype(acc_out_ref.dtype)
        m_out_ref[0, 0] = m_ref[...].astype(m_out_ref.dtype)
        l_out_ref[0, 0] = l_ref[...].astype(l_out_ref.dtype)


def flash_paged_decode(q, k_pages, v_pages, block_tables, kv_len, *,
                       window: Optional[int] = None,
                       scale: Optional[float] = None, acc_dtype=jnp.float32,
                       interpret: bool = False):
    """Flash-decode against a paged KV cache.

    q: [B, Hq, D]; k_pages/v_pages: [Hkv, num_pages, page_size, D] (global page
    pool); block_tables: [B, T] int32 physical page ids per logical KV block
    (entries past a row's allocation must still be valid ids — use the pool's
    trash page 0); kv_len: [B] int32 valid cache length per row.

    Returns o: [B, Hq, D] in q.dtype.
    """
    b, hq, d = q.shape
    hkv, _, page_size, _ = k_pages.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    t = block_tables.shape[1]

    qg = q.reshape(b, hkv, group, d)
    g_pad = max(8, group)
    if g_pad != group:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - group), (0, 0)))

    kernel = functools.partial(_paged_decode_kernel, scale=scale, window=window,
                               block_kv=page_size, acc_dtype=acc_dtype)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, t),
        in_specs=[
            pl.BlockSpec((1, 1, g_pad, d), lambda b_, h, ik, kvl, bt: (b_, h, 0, 0)),
            # the paged gather: logical block ik of row b lives in physical
            # page bt[b, ik] — scalar-prefetched, so the DMA address is known
            # before the body runs (same pattern as the kv_len ragged skip)
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h, ik, kvl, bt: (h, bt[b_, ik], 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h, ik, kvl, bt: (h, bt[b_, ik], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g_pad, d),
                               lambda b_, h, ik, kvl, bt: (b_, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g_pad, d), jnp.float32),
                        pltpu.VMEM((g_pad, LANES), jnp.float32),
                        pltpu.VMEM((g_pad, LANES), jnp.float32)],
    )
    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g_pad, d), q.dtype),
        interpret=interpret,
        **kwargs,
    )(kv_len.astype(jnp.int32), block_tables.astype(jnp.int32), qg,
      k_pages, v_pages)
    return o[:, :, :group].reshape(b, hq, d)


def flash_paged_decode_partials(q, k_pages, v_pages, block_tables, kv_len, *,
                                block_valid=None, window: Optional[int] = None,
                                scale: Optional[float] = None,
                                acc_dtype=jnp.float32,
                                interpret: bool = False):
    """Paged flash-decode returning the un-finalized online-softmax state.

    Same arguments as :func:`flash_paged_decode` plus ``block_valid [B, T]``
    (int32/bool; 0 marks table entries this caller does not own — the
    distributed path passes the locality mask of its pool shard and remaps
    those entries to its local trash page).  Returns the f32 triple
    ``(acc [B,Hq,D], m [B,Hq], l [B,Hq])`` for ``online_softmax.merge`` /
    ``finalize`` — shards of a page-sharded pool each compute their local
    state, then a tiny all-reduce merges them (distributed paged serving).
    """
    b, hq, d = q.shape
    hkv, _, page_size, _ = k_pages.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    t = block_tables.shape[1]
    if block_valid is None:
        block_valid = jnp.ones((b, t), jnp.int32)

    qg = q.reshape(b, hkv, group, d)
    g_pad = max(8, group)
    if g_pad != group:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - group), (0, 0)))

    kernel = functools.partial(_paged_partial_kernel, scale=scale,
                               window=window, block_kv=page_size,
                               acc_dtype=acc_dtype)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out_spec = pl.BlockSpec((1, 1, g_pad, d),
                            lambda b_, h, ik, kvl, bt, bv: (b_, h, 0, 0))
    stat_spec = pl.BlockSpec((1, 1, g_pad, LANES),
                             lambda b_, h, ik, kvl, bt, bv: (b_, h, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, t),
        in_specs=[
            pl.BlockSpec((1, 1, g_pad, d),
                         lambda b_, h, ik, kvl, bt, bv: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h, ik, kvl, bt, bv: (h, bt[b_, ik], 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h, ik, kvl, bt, bv: (h, bt[b_, ik], 0, 0)),
        ],
        out_specs=[out_spec, stat_spec, stat_spec],
        scratch_shapes=[pltpu.VMEM((g_pad, d), jnp.float32),
                        pltpu.VMEM((g_pad, LANES), jnp.float32),
                        pltpu.VMEM((g_pad, LANES), jnp.float32)],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, hkv, g_pad, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, hkv, g_pad, LANES), jnp.float32),
                   jax.ShapeDtypeStruct((b, hkv, g_pad, LANES), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(kv_len.astype(jnp.int32), block_tables.astype(jnp.int32),
      block_valid.astype(jnp.int32), qg, k_pages, v_pages)
    acc = acc[:, :, :group].reshape(b, hq, d)
    m = m[:, :, :group, 0].reshape(b, hq)
    l = l[:, :, :group, 0].reshape(b, hq)
    return acc, m, l


def flash_decode(q, k, v, *, kv_len=None, window: Optional[int] = None,
                 scale: Optional[float] = None, acc_dtype=jnp.float32,
                 block_kv: int = 512, interpret: bool = False):
    """q: [B, Hq, D]; k/v: [B, Hkv, S, D]; kv_len: [B] int32 (default: full S).

    Returns o: [B, Hq, D] in q.dtype.
    """
    b, hq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    if kv_len is None:
        kv_len = jnp.full((b,), skv, jnp.int32)

    block_kv = min(block_kv, skv)
    skv_pad = pl.cdiv(skv, block_kv) * block_kv
    if skv_pad != skv:
        pad = ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nk = skv_pad // block_kv

    # group q heads by kv head: [B, Hkv, G, D], pad G up to the 8-row MXU tile
    qg = q.reshape(b, hkv, group, d)
    g_pad = max(8, group)
    if g_pad != group:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - group), (0, 0)))

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               block_kv=block_kv, acc_dtype=acc_dtype)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g_pad, d), lambda b_, h, ik, _: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda b_, h, ik, _: (b_, h, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda b_, h, ik, _: (b_, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g_pad, d), lambda b_, h, ik, _: (b_, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g_pad, d), jnp.float32),
                        pltpu.VMEM((g_pad, LANES), jnp.float32),
                        pltpu.VMEM((g_pad, LANES), jnp.float32)],
    )
    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g_pad, d), q.dtype),
        interpret=interpret,
        **kwargs,
    )(kv_len.astype(jnp.int32), qg, k, v)
    return o[:, :, :group].reshape(b, hq, d)
