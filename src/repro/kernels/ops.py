"""Public jit-friendly attention ops wiring the Pallas kernels into autodiff.

``mha`` is the trainable fused attention: forward = flash_fwd kernel, backward
= flash_bwd dual-pass kernels (with forward recompute), glued with
``jax.custom_vjp`` exactly the way the paper glues its CUDA kernels into
PyTorch autograd via pybind11.

``AttnConfig`` carries every static option (hashable → usable as a
nondiff argnum). The dropout seed is a *traced* scalar so a jitted train step
can use a fresh seed every step without recompilation.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_bwd import flash_bwd
from repro.kernels.flash_fwd import flash_fwd
from repro.kernels.decode import (flash_decode, flash_paged_decode,
                                  flash_paged_decode_partials)
from repro.kernels import ref


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    """Static attention options (hashable → usable as a jit nondiff argnum)."""
    causal: bool = False
    window: Optional[int] = None
    scale: Optional[float] = None
    dropout_rate: float = 0.0
    acc_dtype: Any = jnp.float32       # bf16-ACC / f32-ACC (paper §3.1)
    bwd_acc_dtype: Any = jnp.float32   # paper uses fp16-ACC for backward
    block_q: int = 128
    block_kv: int = 128
    interpret: bool = False


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _mha(q, k, v, seed, segment_ids, cfg: AttnConfig):
    o, _ = flash_fwd(q, k, v, causal=cfg.causal, window=cfg.window,
                     scale=cfg.scale, dropout_rate=cfg.dropout_rate,
                     dropout_seed=seed, segment_ids=segment_ids,
                     acc_dtype=cfg.acc_dtype,
                     block_q=cfg.block_q, block_kv=cfg.block_kv,
                     interpret=cfg.interpret)
    return o


def _mha_fwd(q, k, v, seed, segment_ids, cfg: AttnConfig):
    o, lse = flash_fwd(q, k, v, causal=cfg.causal, window=cfg.window,
                       scale=cfg.scale, dropout_rate=cfg.dropout_rate,
                       dropout_seed=seed, segment_ids=segment_ids,
                       acc_dtype=cfg.acc_dtype,
                       block_q=cfg.block_q, block_kv=cfg.block_kv,
                       interpret=cfg.interpret)
    # Residuals: q,k,v + (o, lse) — S/P are recomputed in the backward kernels,
    # the paper's memory-saving choice (§3.3).
    return o, (q, k, v, o, lse, seed, segment_ids)


def _mha_bwd(cfg: AttnConfig, res, do):
    q, k, v, o, lse, seed, segment_ids = res
    dq, dk, dv = flash_bwd(q, k, v, o, lse, do, causal=cfg.causal,
                           window=cfg.window, scale=cfg.scale,
                           dropout_rate=cfg.dropout_rate, dropout_seed=seed,
                           segment_ids=segment_ids,
                           acc_dtype=cfg.bwd_acc_dtype,
                           block_q=cfg.block_q, block_kv=cfg.block_kv,
                           interpret=cfg.interpret)
    return dq, dk, dv, None, None


_mha.defvjp(_mha_fwd, _mha_bwd)


def mha(q, k, v, *, seed=0, segment_ids=None,
        config: AttnConfig = AttnConfig()):
    """Fused multi-head attention, differentiable.

    q: [B, Hq, Sq, D], k/v: [B, Hkv, Skv, D] → o: [B, Hq, Sq, D].
    segment_ids: optional [B, Skv] int32 per-token segment ids (packed/varlen
    batches); attention never crosses a segment boundary, negative ids mark
    padding. Carried as a traced residual (not in AttnConfig, which must stay
    hashable for the nondiff argnum) so a jitted train step can feed a fresh
    packing layout every step without recompilation.
    """
    seed = jnp.asarray(seed, jnp.int32)
    return _mha(q, k, v, seed, segment_ids, config)


def mha_reference(q, k, v, *, seed=0, segment_ids=None,
                  config: AttnConfig = AttnConfig()):
    """The unfused oracle with identical semantics (paper's PyTorch baseline)."""
    return ref.naive_mha(q, k, v, causal=config.causal, window=config.window,
                         scale=config.scale, dropout_rate=config.dropout_rate,
                         dropout_seed=seed, segment_ids=segment_ids,
                         acc_dtype=jnp.float32)


def mha_xla(q, k, v, *, seed=0, segment_ids=None,
            config: AttnConfig = AttnConfig(),
            chunk: int = 1024, unroll: bool = False):
    """The fused algorithm in plain XLA ops (dry-run / CPU-runnable path)."""
    return ref.online_mha(q, k, v, causal=config.causal, window=config.window,
                          scale=config.scale, dropout_rate=config.dropout_rate,
                          dropout_seed=seed, segment_ids=segment_ids,
                          acc_dtype=jnp.float32, chunk=chunk,
                          unroll=unroll)


def decode(q, k, v, *, kv_len=None, window=None, scale=None,
           block_kv: int = 512, num_splits: int = 1, interpret: bool = False):
    """Single-token flash-decode. q: [B, Hq, D], k/v: [B, Hkv, S, D].

    ``num_splits > 1`` partitions the KV axis over parallel grid cells whose
    partial states merge in f32 (split-KV; see kernels/decode.py and
    perf/autotune.py for the launch-parameter choice).
    """
    return flash_decode(q, k, v, kv_len=kv_len, window=window, scale=scale,
                        block_kv=block_kv, num_splits=num_splits,
                        interpret=interpret)


def paged_decode(q, k_pages, v_pages, block_tables, kv_len, *, window=None,
                 scale=None, num_splits: int = 1, interpret: bool = False):
    """Single-token flash-decode over a paged KV cache.

    q: [B, Hq, D]; k_pages/v_pages: [Hkv, num_pages, page_size, D];
    block_tables: [B, T] int32 (trash-page ids past each row's allocation);
    kv_len: [B] int32. ``num_splits`` splits the table width (see ``decode``).
    """
    return flash_paged_decode(q, k_pages, v_pages, block_tables, kv_len,
                              window=window, scale=scale,
                              num_splits=num_splits, interpret=interpret)


def paged_decode_partials(q, k_pages, v_pages, block_tables, kv_len, *,
                          block_valid=None, window=None, scale=None,
                          num_splits: int = 1, interpret: bool = False):
    """Paged flash-decode stopping at the (acc, m, l) online-softmax state.

    ``block_valid [B, T]`` (0/1) gates table entries — a shard of a
    page-sharded pool passes its locality mask so non-local entries (remapped
    to the local trash page) are skipped. States from different shards merge
    with ``online_softmax.merge`` and finalize once (distributed serving).
    ``num_splits`` splits shard-locally first; the returned triple is
    identical either way, so it composes with the cross-shard merge.
    """
    return flash_paged_decode_partials(q, k_pages, v_pages, block_tables,
                                       kv_len, block_valid=block_valid,
                                       window=window, scale=scale,
                                       num_splits=num_splits,
                                       interpret=interpret)


def gather_pages(pages, block_tables):
    """Materialise a paged pool as a contiguous cache (XLA / oracle path).

    pages [Hkv, num_pages, page_size, D], block_tables [B, T] →
    [B, Hkv, T*page_size, D].
    """
    hkv, _, ps, d = pages.shape
    b, t = block_tables.shape
    g = pages[:, block_tables]                    # [Hkv, B, T, ps, D]
    return g.transpose(1, 0, 2, 3, 4).reshape(b, hkv, t * ps, d)


def paged_decode_reference(q, k_pages, v_pages, block_tables, kv_len, *,
                           window=None, scale=None):
    """Oracle: gather the pages contiguously, then the contiguous oracle."""
    return decode_reference(q, gather_pages(k_pages, block_tables),
                            gather_pages(v_pages, block_tables),
                            kv_len=kv_len, window=window, scale=scale)


def decode_reference(q, k, v, *, kv_len=None, window=None, scale=None):
    """Oracle for decode (handles ragged kv_len row by row via masking)."""
    b, hq, d = q.shape
    skv = k.shape[2]
    if kv_len is None:
        return ref.naive_mha(q[:, :, None, :], k, v, causal=True,
                             window=window, scale=scale)[:, :, 0, :]
    outs = []
    for i in range(b):
        L = int(kv_len[i])
        outs.append(ref.naive_mha(q[i:i + 1, :, None, :], k[i:i + 1, :, :L],
                                  v[i:i + 1, :, :L], causal=True,
                                  window=window, scale=scale)[:, :, 0, :])
    return jnp.concatenate(outs, axis=0)
