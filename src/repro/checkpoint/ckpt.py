"""Sharding-aware atomic checkpoints with async save and resume-from-latest.

Design points for 1000+-node deployments:

* **Atomicity**: writes go to ``step_XXXXXXXX.tmp/`` and are committed with a
  single directory rename — a preempted save can never produce a half
  checkpoint that resume would pick up.
* **Mesh-agnostic**: tensors are saved as host numpy (gathered per-process
  addressable shards); restore places them under *any* new mesh/sharding —
  this is what makes elastic re-scaling a restore-time concern only.
* **Async**: ``save_async`` snapshots to host then writes on a background
  thread so the train loop only blocks for the device→host copy.
* **Self-describing**: tree structure + dtypes + step live in metadata.json;
  arrays live in one .npz per process (single-process CPU container ⇒ one).
* **Integrity**: metadata records a blake2b digest of the array payload;
  restore verifies it *before* deserialization and raises
  :class:`CorruptCheckpointError` on mismatch — a truncated or bit-flipped
  checkpoint fails with a clear message instead of deep inside np.load.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"


class CorruptCheckpointError(RuntimeError):
    """A checkpoint's array payload does not match its recorded digest —
    truncated write, bit rot, or manual tampering.  Restore from an older
    step (the keep ring holds several) rather than deserializing garbage."""


def _digest_file(path: str) -> str:
    h = hashlib.blake2b(digest_size=16)
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.isfile(os.path.join(ckpt_dir, d, "metadata.json"))]
    return max(steps) if steps else None


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Blocking atomic save. Returns the committed directory."""
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    return _write(ckpt_dir, step, flat, jax.tree.structure(tree), keep)


def save_async(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> threading.Thread:
    """Device→host copy now; disk write on a background thread."""
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}  # sync copy
    treedef = jax.tree.structure(tree)
    t = threading.Thread(target=_write,
                         args=(ckpt_dir, step, flat, treedef, keep),
                         daemon=True)
    t.start()
    return t


def _write(ckpt_dir, step, flat, treedef, keep):
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = {"step": step, "keys": sorted(flat.keys()),
            "treedef": str(treedef),
            "digest": _digest_file(os.path.join(tmp, "arrays.npz")),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()}}
    with open(os.path.join(tmp, "metadata.json"), "w") as f:
        json.dump(meta, f)
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir, keep):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def restore(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of ``like``. ``shardings`` (same pytree
    structure, NamedSharding leaves) re-shards under a possibly different mesh
    — the elastic-restart path."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "metadata.json")) as f:
        meta = json.load(f)
    want = meta.get("digest")  # absent in pre-digest checkpoints: accepted
    if want is not None:
        got = _digest_file(os.path.join(d, "arrays.npz"))
        if got != want:
            raise CorruptCheckpointError(
                f"checkpoint {d} failed integrity check: arrays.npz digest "
                f"{got} != recorded {want} (truncated or corrupted write?)")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat_like = _flatten(like)
        missing = set(flat_like) - set(z.files)
        if missing:
            raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}")
        arrays = {k: z[k] for k in flat_like}
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    restored = []
    shard_flat = _flatten(shardings) if shardings is not None else {}
    for key, leaf in zip(keys, leaves_like):
        arr = arrays[key].astype(leaf.dtype) if hasattr(leaf, "dtype") \
            else arrays[key]
        if key in shard_flat and shard_flat[key] is not None:
            arr = jax.device_put(arr, shard_flat[key])
        restored.append(arr)
    return jax.tree_util.tree_unflatten(treedef, restored)
