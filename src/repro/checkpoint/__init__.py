from repro.checkpoint.ckpt import (CorruptCheckpointError, latest_step,
                                   restore, save, save_async)

__all__ = ["CorruptCheckpointError", "latest_step", "restore", "save",
           "save_async"]
